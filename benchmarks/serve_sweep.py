"""Serving config-matrix sweep: mesh shape x batch bucket x strategy.

    PYTHONPATH=src python benchmarks/serve_sweep.py --smoke \
        --out serve_sweep.json
    PYTHONPATH=src python benchmarks/serve_sweep.py --report serve_sweep.json
    PYTHONPATH=src python benchmarks/serve_sweep.py --smoke \
        --baseline serve_sweep_prev.json

Each cell AOT-warms a ``repro.serve.Server`` for one (mesh, bucket,
strategy) config on forced-host devices (``SERVE_SWEEP_DEVICES`` env,
default 8 -- the flag must precede the jax import), serves a fixed
synthetic request batch, and records tokens/s/device, TTFT, p50/p99
per-token decode latency, the serve-window plan-cache hit rate, and
whether the plan-routed greedy tokens match the unrouted ``1x1``
baseline bitwise.  Output is a schema'd JSON (``repro.serve_sweep/v1``);
``--report`` renders it as a table (null-latency rows -- e.g.
``--max-new 1`` -- print as '-'), ``--baseline`` diffs tokens/s per cell
against a previous run and exits nonzero when a cell regresses beyond
``SERVE_SWEEP_MARGIN`` (default 25%: host-CPU serving is noisy).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

SCHEMA = "repro.serve_sweep/v1"

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _force_host_devices() -> int:
    devices = int(os.environ.get("SERVE_SWEEP_DEVICES", "8"))
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={devices}"
            .strip())
    return devices


# (mesh label, mesh shape or None, strategy or None=auto).  2x2 exercises
# the torus families, 1x4 the ring/collective families, 1x1 is the
# unrouted baseline every routed cell's greedy tokens must match bitwise.
DEFAULT_GRID = (
    ("1x1", None, None),
    ("2x2", (2, 2), None),
    ("2x2", (2, 2), "cannon"),
    ("2x2", (2, 2), "summa"),
    ("1x4", (1, 4), None),
)


def _mesh(shape):
    import jax

    if shape is None:
        return None
    n = shape[0] * shape[1]
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return jax.make_mesh(shape, ("x", "y"), devices=devs[:n])


def _prompts(rng, n, lo=2, hi=10, vocab=200):
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def run_sweep(args) -> dict:
    n_devices = _force_host_devices()
    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models.registry import build_model
    from repro.plan import cache_clear
    from repro.runtime.serve import ServeConfig
    from repro.serve import Server, bucket_grid

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.dtype:
        import dataclasses

        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    scfg = ServeConfig(max_new_tokens=args.max_new, max_seq=args.max_seq)
    buckets = bucket_grid(args.batches, args.seqs)
    rng = np.random.default_rng(args.seed)
    requests = {b: _prompts(rng, max(1, b.batch - 1), hi=min(10, b.seq + 1),
                            vocab=cfg.vocab_size)
                for b in buckets}

    # the unrouted baseline tokens per bucket, for bitwise comparison
    cache_clear()
    base = Server(model, params, scfg, buckets=buckets)
    base.warmup()
    baseline_tokens = {b: base.generate(requests[b]).sequences
                       for b in buckets}

    cells = []
    for mesh_label, mesh_shape, strategy in DEFAULT_GRID:
        try:
            mesh = _mesh(mesh_shape)
        except RuntimeError as e:
            for b in buckets:
                cells.append({"mesh": mesh_label, "bucket": b.label,
                              "strategy": strategy or "auto", "ok": False,
                              "error": str(e)})
            continue
        cache_clear()
        try:
            srv = Server(model, params, scfg, mesh=mesh, strategy=strategy,
                         buckets=buckets)
            t0 = time.perf_counter()
            warm = srv.warmup()
            warm_s = time.perf_counter() - t0
        except Exception:
            for b in buckets:
                cells.append({"mesh": mesh_label, "bucket": b.label,
                              "strategy": strategy or "auto", "ok": False,
                              "error": traceback.format_exc(limit=1)})
            continue
        for b in buckets:
            cells.append(_run_cell(srv, b, requests[b], baseline_tokens[b],
                                   mesh_label, strategy, warm[b.label],
                                   warm_s, n_devices if mesh else 1))
    return {
        "schema": SCHEMA,
        "arch": cfg.name,
        "created_unix": int(time.time()),
        "config": {"max_new_tokens": scfg.max_new_tokens,
                   "max_seq": scfg.max_seq, "devices": n_devices,
                   "buckets": [b.label for b in buckets]},
        "cells": cells,
    }


def _run_cell(srv, bucket, prompts, baseline, mesh_label, strategy,
              warm_info, warm_s, n_dev) -> dict:
    try:
        res = srv.generate(prompts)
        rep = srv.cache_report()
        q = res.latency_quantiles_ms()
        sw = rep.get("serve_window") or {}
        return {
            "mesh": mesh_label,
            "bucket": bucket.label,
            "strategy": strategy or "auto",
            "ok": True,
            "routed": res.bucket is not None and srv.mesh is not None,
            "plans": warm_info["plans"],
            "warmup_s": round(warm_s, 4),
            "tokens_per_s": round(res.tokens_per_s, 2),
            "tokens_per_s_per_device": round(res.tokens_per_s / n_dev, 2),
            "ttft_ms": round(res.ttft_s * 1e3, 3),
            "p50_ms": None if q["p50_ms"] is None else round(q["p50_ms"], 3),
            "p99_ms": None if q["p99_ms"] is None else round(q["p99_ms"], 3),
            "cache_hit_rate": sw.get("hit_rate"),
            "match_baseline": res.sequences == baseline,
            "error": None,
        }
    except Exception:
        return {"mesh": mesh_label, "bucket": bucket.label,
                "strategy": strategy or "auto", "ok": False,
                "error": traceback.format_exc(limit=1)}


def render_report(data) -> str:
    from repro.launch.report import serve_sweep_table

    return serve_sweep_table(data)


def _cell_key(c):
    return (c["mesh"], c["bucket"], c["strategy"])


def diff_baseline(data, baseline_data, margin: float):
    """Per-cell tokens/s regression vs a previous sweep JSON; returns the
    list of regressed cells."""
    old = {_cell_key(c): c for c in baseline_data["cells"] if c.get("ok")}
    regressions = []
    lines = []
    for c in data["cells"]:
        if not c.get("ok"):
            continue
        prev = old.get(_cell_key(c))
        if prev is None:
            continue
        now, was = c["tokens_per_s"], prev["tokens_per_s"]
        delta = (now - was) / was if was else 0.0
        flag = ""
        if now < was * (1.0 - margin):
            regressions.append(c)
            flag = "  <-- REGRESSION"
        lines.append(f"{c['mesh']} {c['bucket']} {c['strategy']}: "
                     f"{was:.1f} -> {now:.1f} tok/s ({delta:+.0%}){flag}")
    return regressions, lines


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--batches", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--seqs", type=int, nargs="+", default=[16])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32",
                    help="model compute dtype; float32 (default) keeps "
                         "greedy argmax margins far above the accumulation-"
                         "order noise between schedules, so routed tokens "
                         "compare bitwise against the unrouted baseline")
    ap.add_argument("--out", default="serve_sweep.json")
    ap.add_argument("--report", metavar="JSON",
                    help="render a sweep JSON as a table and exit")
    ap.add_argument("--baseline", metavar="JSON",
                    help="diff tokens/s against a previous sweep JSON")
    args = ap.parse_args()

    if args.report:
        with open(args.report) as f:
            data = json.load(f)
        if data.get("schema") != SCHEMA:
            print(f"not a serve-sweep JSON (schema={data.get('schema')!r})")
            return 2
        print(f"### Serve sweep: {data['arch']} "
              f"(max_new={data['config']['max_new_tokens']}, "
              f"{data['config']['devices']} devices)\n")
        print(render_report(data))
        return 0

    data = run_sweep(args)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=1)
    print(render_report(data))
    bad = [c for c in data["cells"]
           if c.get("ok") and not c["match_baseline"]]
    errs = [c for c in data["cells"] if not c.get("ok")]
    print(f"# {len(data['cells'])} cells, {len(errs)} errors, "
          f"{len(bad)} baseline mismatches -> {args.out}")

    rc = 1 if (bad or errs) else 0
    if args.baseline:
        margin = float(os.environ.get("SERVE_SWEEP_MARGIN", "0.25"))
        with open(args.baseline) as f:
            prev = json.load(f)
        regressions, lines = diff_baseline(data, prev, margin)
        print(f"\n# baseline diff vs {args.baseline} (margin {margin:.0%})")
        for ln in lines:
            print(ln)
        if regressions:
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
