"""One benchmark per paper table/figure.  Each returns (name, us_per_call,
derived) rows for the CSV emitted by benchmarks.run.

``us_per_call`` is ``None`` for derived-only benches (pure model
evaluations with no timed call) -- the driver emits an empty CSV field and
``"us_per_call": null`` in the JSON, never a fake ``0.0``.

Multi-device benches (collective-byte measurements) run in a subprocess
with fake devices so the parent process keeps the default 1-device view.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Tuple

import numpy as np

Row = Tuple[str, Optional[float], str]


def _timeit(fn, reps: int = 3) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


# -- Sec. 4.1 / Fig. 13: Cannon on the 2D torus ----------------------------


def bench_cannon_solver() -> List[Row]:
    """The systematic procedure itself: solve the commutative diagram for
    q=7 and verify it recovers the Cannon family at minimum cost."""
    from repro.core import solve_torus, is_cannon_like, cannon_schedule

    q = 7
    us = _timeit(lambda: solve_torus(q), reps=1)
    sols = solve_torus(q)
    cs = cannon_schedule(q)
    exact = any(s.schedule.M == cs.M for s in sols)
    return [
        ("cannon_solver_q7", us,
         f"solutions={len(sols)};min_hops={sols[0].hop_cost};"
         f"cannon_found={exact};first_is_cannon_like={is_cannon_like(sols[0])}"),
    ]


def _link_weighted(by_kind: dict, q: int) -> float:
    """Paper Sec. 2.4: cost = bytes x link transits under a torus routing
    policy.  One-hop collective-permute = 1 transit/byte (Cannon's mu);
    ring all-reduce = 2(q-1)/q x q ~ 2(q-1); all-gather/reduce-scatter =
    q-1; all-to-all ~ q/2."""
    w = {"collective-permute": 1.0, "all-reduce": 2.0 * (q - 1),
         "all-gather": float(q - 1), "reduce-scatter": float(q - 1),
         "all-to-all": q / 2.0}
    return sum(by_kind.get(k, 0) * f for k, f in w.items())


def bench_cannon_comm() -> List[Row]:
    """Fig. 13 / Sec. 4.1: Cannon vs SUMMA on a 4x4 torus (subprocess, 16
    fake devices).  Per-device HLO collective bytes + the paper's
    link-transit-weighted cost vs the analytic one-hop model."""
    out = _run_dist_probe("cannon_summa")
    rows = []
    n, q = out["n"], out["q"]
    # analytic: A and B each move one hop per step for q steps (incl. the
    # skew); per device = 2 tensors x q steps x block bytes
    block = (n // q) * (n // q) * 2
    analytic = 2 * q * block
    cw = _link_weighted(out["cannon_kinds"], q)
    sw = _link_weighted(out["summa_kinds"], q)
    rows.append((
        "cannon_comm_4x4", out["cannon_us"],
        f"perdev_bytes={out['cannon_bytes']:.3e};analytic={analytic:.3e};"
        f"ratio={out['cannon_bytes']/analytic:.2f};linkweighted={cw:.3e}",
    ))
    rows.append((
        "summa_comm_4x4", out["summa_us"],
        f"perdev_bytes={out['summa_bytes']:.3e};linkweighted={sw:.3e};"
        f"linkweighted_vs_cannon={sw/max(cw,1):.2f}x",
    ))
    return rows


# -- Sec. D.1: 2.5D replication ---------------------------------------------


def bench_25d_comm() -> List[Row]:
    """Sec. D.1: with c-fold replication each layer runs only t = q/c of
    the Cannon steps; per-device communication drops while p grows by c
    (the memory-for-communication trade).  Compares 2D Cannon on q x q
    against the composed 2.5D schedule on q x q x c for the same matmul."""
    out = _run_dist_probe("pod25d")
    c1_dev = out["c1_bytes"]          # 2D cannon p=q^2, per device
    c2_dev = out["c2_bytes"]          # 2.5D p=c q^2, per device
    p_ratio = out["c"]
    return [(
        "comm_25d_c2_vs_c1", out["us"],
        f"cannon_p{out['q']**2}_perdev={c1_dev:.3e};"
        f"c25d_p{out['c']*out['q']**2}_perdev={c2_dev:.3e};"
        f"perdev_reduction={c1_dev/max(c2_dev,1):.2f}x_at_{p_ratio}x_devices",
    )]


# -- Sec. 4.2 Fig. 11-12: fat-tree recursive schedule -----------------------


def bench_fattree() -> List[Row]:
    from repro.core.fattree import FatTreeSchedule

    rows = []
    for d in (2, 3):
        ft = FatTreeSchedule(d=d)
        us = _timeit(lambda ft=ft: ft.link_traffic(), reps=1)
        traffic = ft.link_traffic()
        top = ft.top_level_words()
        n2 = ft.n ** 2
        rows.append((
            f"fattree_d{d}", us,
            f"valid={ft.validate()};top_words={top};n^2={n2};"
            f"matches_paper_min={top == n2}",
        ))
    return rows


# -- Sec. 4.3: space-bounded / Z-order --------------------------------------


def bench_spacebounded() -> List[Row]:
    from repro.core.zorder import (block_reuse_distance_traffic,
                                   rowmajor_schedule, zorder_schedule)

    g = 16  # 16^3 = 4096-step block grid
    rows = []
    z = zorder_schedule(g, g, g)
    r = rowmajor_schedule(g, g, g)
    for cache in (48, 192, 768):
        tz = block_reuse_distance_traffic(z, cache)
        tr = block_reuse_distance_traffic(r, cache)
        rows.append((
            f"zorder_traffic_M{cache}", None,
            f"zorder={tz};rowmajor={tr};saving={tr/tz:.2f}x",
        ))
    us = _timeit(lambda: zorder_schedule(g, g, g), reps=1)
    rows.append((f"zorder_gen_{g}^3", us, f"steps={len(z)}"))
    return rows


# -- Sec. D.2: hexagonal systolic array -------------------------------------


def bench_hex() -> List[Row]:
    from repro.core.hexarray import HexSchedule

    q = 8
    hs = HexSchedule(q=q)
    A = np.random.rand(q, q)
    B = np.random.rand(q, q)
    us = _timeit(lambda: hs.simulate(A, B), reps=1)
    props = hs.systolic_properties()
    ok = np.allclose(hs.simulate(A, B), hs.reference(A, B))
    return [(
        f"hex_systolic_q{q}", us,
        f"correct={ok};steps={hs.num_steps};props={all(props.values())}",
    )]


# -- Sec. 2.4 + [20,11]: lower bounds ----------------------------------------


def bench_lowerbound() -> List[Row]:
    from repro.core.cost import (bandwidth_lower_bound, cannon_comm_total,
                                 memory_independent_lower_bound)

    n, p = 8192, 64
    M = 3 * n * n / p  # one copy of A,B,C
    per_node = cannon_comm_total(n, p) / p
    lb = max(bandwidth_lower_bound(n, p, M), memory_independent_lower_bound(n, p))
    return [(
        "lowerbound_gap_n8192_p64", None,
        f"cannon_per_node={per_node:.3e};bound={lb:.3e};"
        f"factor_above_bound={per_node/lb:.2f}",
    )]


# -- kernels ------------------------------------------------------------------


def bench_matmul_kernel() -> List[Row]:
    import jax
    import jax.numpy as jnp
    from repro.kernels.matmul import matmul, matmul_ref

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    out = matmul(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))
    ref = jax.jit(matmul_ref)
    us = _timeit(lambda: jax.block_until_ready(ref(a, b)))
    return [(
        "zorder_matmul_256", us, f"interpret_max_err={err:.2e}",
    )]


def bench_flash_kernel() -> List[Row]:
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import attention_ref, mha

    B, S, H, D = 1, 512, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, D), jnp.float32)
    out = mha(q, k, v, causal=True, block_q=128, block_kv=128, interpret=True)

    def ref():
        qh = q.transpose(0, 2, 1, 3).reshape(-1, S, D)
        kh = k.transpose(0, 2, 1, 3).reshape(-1, S, D)
        vh = v.transpose(0, 2, 1, 3).reshape(-1, S, D)
        o = attention_ref(qh, kh, vh, causal=True)
        return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)

    err = float(jnp.max(jnp.abs(out - ref())))
    import jax as _jax
    jref = _jax.jit(lambda: ref())
    us = _timeit(lambda: _jax.block_until_ready(jref()))
    return [("flash_attention_512", us, f"interpret_max_err={err:.2e}")]


# -- strategy cost model -------------------------------------------------------


def bench_strategy_choice() -> List[Row]:
    from repro.dist.api import choose, estimate

    m, n, k, tp = 32768, 8192, 2048, 16
    rows = []
    best = choose(m, n, k, tp=tp)
    xla = estimate("xla_ag", m, n, k, tp)
    ring = estimate("ring_ag", m, n, k, tp)
    rows.append((
        "strategy_autoselect", None,
        f"choice={best};xla_total={xla.total_s:.2e};ring_total={ring.total_s:.2e};"
        f"overlap_speedup={xla.total_s/ring.total_s:.2f}x",
    ))
    return rows


def bench_plan_dispatch() -> List[Row]:
    """Plan-engine dispatch overhead + cache behaviour: repeated
    ``symmetric_matmul`` calls must hit the plan cache (a miss storm here
    is a dispatch regression -- this bench raises so the CI smoke job
    fails loudly)."""
    import jax
    import jax.numpy as jnp
    from repro import plan as planlib
    from repro.dist.api import symmetric_matmul

    planlib.cache_clear()
    a = jax.random.normal(jax.random.PRNGKey(0), (192, 160), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (160, 128), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(symmetric_matmul(a, b)))
    s = planlib.cache_stats()
    if s["hits"] < 3:  # warmup + 3 timed reps -> >= 3 hits after 1 miss
        raise RuntimeError(f"plan cache not hitting on repeat calls: {s}")
    # batched dispatch reuses the same plan entry family
    xb = jax.random.normal(jax.random.PRNGKey(2), (4, 48, 160), jnp.float32)
    out = symmetric_matmul(xb, b)
    assert out.shape == (4, 48, 128)
    return [(
        "plan_dispatch_local", us,
        f"hits={s['hits']};misses={s['misses']};entries={s['size']}",
    )]


# -- overlapped vs staged execution -------------------------------------------

_OVERLAP_PROBE = r"""
import os, sys
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=" + sys.argv[1])
import json, time
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from repro.plan import build_plan
from repro.plan.lower_shard_map import _lower_shard_map

q, n = 2, 512
devs = np.array(jax.devices())
mesh = jax.make_mesh((q, q), ("x", "y"), devices=devs[:q*q])
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
out = {"q": q, "n": n}
results = {}
for name, ov in (("staged", False), ("overlapped", True)):
    plan = build_plan(n, n, n, mesh=mesh, strategy="cannon",
                      a_dtype=a.dtype, b_dtype=b.dtype,
                      overlap=ov, use_cache=False)
    f = jax.jit(_lower_shard_map(plan))
    results[name] = np.asarray(jax.block_until_ready(f(a, b)))
    best = float("inf")
    for _ in range(7):
        t0 = time.perf_counter()
        jax.block_until_ready(f(a, b))
        best = min(best, time.perf_counter() - t0)
    out[name + "_us"] = best * 1e6
out["bitwise_equal"] = bool(
    np.array_equal(results["staged"], results["overlapped"]))
print("PROBE_JSON:" + json.dumps(out))
"""


def bench_overlap_vs_staged() -> List[Row]:
    """Paired staged-vs-overlapped cannon on a forced-host 2x2 mesh: both
    variants' us_per_call plus the speedup ratio.  CI guard: raises when
    the overlapped body is slower than the staged one beyond the
    ``OVERLAP_DRIFT_MARGIN`` fraction (default 10%) -- host-CPU timing is
    noisy, so the margin absorbs jitter while still catching a pessimized
    double-buffer lowering.  Also asserts bitwise-identical outputs (the
    overlapped torus body is a pure dataflow reorder)."""
    margin = float(os.environ.get("OVERLAP_DRIFT_MARGIN", "0.10"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _OVERLAP_PROBE, "4"],
        capture_output=True, text=True, env=env, cwd=_repo_root(),
        timeout=600,
    )
    out = None
    for line in res.stdout.splitlines():
        if line.startswith("PROBE_JSON:"):
            out = json.loads(line[len("PROBE_JSON:"):])
    if out is None:
        raise RuntimeError(
            f"overlap probe failed:\n{res.stdout[-2000:]}\n"
            f"{res.stderr[-2000:]}")
    staged, over = out["staged_us"], out["overlapped_us"]
    speedup = staged / max(over, 1e-9)
    rows = [
        ("overlap_vs_staged_cannon_2x2", over,
         f"staged_us={staged:.1f};overlapped_us={over:.1f};"
         f"speedup={speedup:.2f}x;bitwise_equal={out['bitwise_equal']};"
         f"margin={margin:.2f}"),
        ("overlap_vs_staged_cannon_2x2_staged_ref", staged,
         f"n={out['n']};q={out['q']}"),
    ]
    if not out["bitwise_equal"]:
        raise RuntimeError(
            "overlapped cannon output differs bitwise from staged")
    if over > staged * (1.0 + margin):
        raise RuntimeError(
            f"overlapped cannon slower than staged beyond margin: "
            f"{over:.1f}us vs {staged:.1f}us (margin {margin:.0%})")
    return rows


# -- hierarchical fat-tree vs flat pod execution ------------------------------

_FATTREE_PROBE = r"""
import os, sys
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=" + sys.argv[1])
import json, time
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from repro.plan import build_plan
from repro.plan.lower_shard_map import _lower_shard_map

n = 512
devs = np.array(jax.devices())
mesh = jax.make_mesh((2, 2, 2), ("tree", "x", "y"), devices=devs[:8])
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
ref = np.asarray(a @ b)
out = {"n": n, "mesh": "2x2x2"}
for name in ("fattree", "pod25d"):
    plan = build_plan(n, n, n, mesh=mesh, strategy=name,
                      a_dtype=a.dtype, b_dtype=b.dtype, use_cache=False)
    f = jax.jit(_lower_shard_map(plan))
    got = np.asarray(jax.block_until_ready(f(a, b)))
    out[name + "_ok"] = bool(np.allclose(got, ref, atol=1e-2))
    best = float("inf")
    for _ in range(7):
        t0 = time.perf_counter()
        jax.block_until_ready(f(a, b))
        best = min(best, time.perf_counter() - t0)
    out[name + "_us"] = best * 1e6
print("PROBE_JSON:" + json.dumps(out))
"""


def bench_fattree_vs_flat() -> List[Row]:
    """The hierarchical fat-tree lowering against the flat 2.5D pod plan on
    the same pod-of-pods mesh (2 pods x 2x2, 8 forced-host devices): both
    must be numerically correct; the timings contrast the recursive
    tree-axis exchange program with the replicate--reduce program.  No
    speed guard -- on host CPU the two are link-indistinguishable; the
    ranking between them is the calibrated profile's job (see
    tests/test_fattree_exec.py's flip pin)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _FATTREE_PROBE, "8"],
        capture_output=True, text=True, env=env, cwd=_repo_root(),
        timeout=600,
    )
    out = None
    for line in res.stdout.splitlines():
        if line.startswith("PROBE_JSON:"):
            out = json.loads(line[len("PROBE_JSON:"):])
    if out is None:
        raise RuntimeError(
            f"fattree probe failed:\n{res.stdout[-2000:]}\n"
            f"{res.stderr[-2000:]}")
    if not (out["fattree_ok"] and out["pod25d_ok"]):
        raise RuntimeError(f"fattree-vs-flat numeric mismatch: {out}")
    ft, flat = out["fattree_us"], out["pod25d_us"]
    return [
        ("fattree_vs_flat_2x2x2", ft,
         f"fattree_us={ft:.1f};pod25d_us={flat:.1f};"
         f"ratio={ft / max(flat, 1e-9):.2f};n={out['n']};ok=True"),
    ]


# -- subprocess probe ----------------------------------------------------------

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=48"
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
import functools
sys.path.insert(0, "src")
from repro.dist import cannon_matmul, summa_matmul, pod25d_matmul
from repro.dist.pod25d import cannon25d_matmul
from repro.roofline.hlo_stats import analyze

mode = sys.argv[1]
devs = np.array(jax.devices())
out = {}
if mode == "cannon_summa":
    q, n = 4, 1024
    mesh = jax.make_mesh((q, q), ("x", "y"), devices=devs[:q*q])
    a = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    for name, fn in (("cannon", cannon_matmul), ("summa", summa_matmul)):
        f = jax.jit(functools.partial(fn, mesh=mesh, axis_x="x", axis_y="y"))
        t0 = time.perf_counter()
        comp = f.lower(a, b).compile()
        stats = analyze(comp.as_text())
        out[name + "_bytes"] = stats.coll_bytes       # per device
        out[name + "_kinds"] = {k: int(v) for k, v in stats.coll.items()}
        out[name + "_us"] = (time.perf_counter() - t0) * 1e6
    out["n"], out["q"] = n, q
elif mode == "pod25d":
    n = 1024
    a = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    q, c = 4, 2
    mesh1 = jax.make_mesh((q, q), ("x", "y"), devices=devs[:q*q])
    f1 = jax.jit(functools.partial(cannon_matmul, mesh=mesh1, axis_x="x", axis_y="y"))
    t0 = time.perf_counter()
    s1 = analyze(f1.lower(a, b).compile().as_text())
    mesh2 = jax.make_mesh((c, q, q), ("pod", "x", "y"), devices=devs[:c*q*q])
    f2 = jax.jit(functools.partial(cannon25d_matmul, mesh=mesh2,
                                   pod_axis="pod", axis_x="x", axis_y="y"))
    s2 = analyze(f2.lower(a, b).compile().as_text())
    out["c1_bytes"] = s1.coll_bytes   # per device (2D cannon, p=16)
    out["c2_bytes"] = s2.coll_bytes   # per device (2.5D c=2, p=32)
    out["c1_kinds"] = {k: int(v) for k, v in s1.coll.items()}
    out["c2_kinds"] = {k: int(v) for k, v in s2.coll.items()}
    out["q"], out["c"] = q, c
    out["us"] = (time.perf_counter() - t0) * 1e6
print("PROBE_JSON:" + json.dumps(out))
"""


def _run_dist_probe(mode: str) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _PROBE, mode],
        capture_output=True, text=True, env=env, cwd=_repo_root(), timeout=600,
    )
    for line in res.stdout.splitlines():
        if line.startswith("PROBE_JSON:"):
            return json.loads(line[len("PROBE_JSON:"):])
    raise RuntimeError(
        f"probe {mode} failed:\n{res.stdout[-2000:]}\n{res.stderr[-2000:]}"
    )


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- repro.tune: measured autotuning vs the default_blocks heuristic --------


def bench_tuned_vs_default() -> List[Row]:
    """Tuned blocks vs ``default_blocks`` on three shapes: square, ragged,
    and the MoE expert GEMM from ``configs/deepseek_moe_16b`` (per-token
    expert d_model x moe_d_ff, clamped for CI).  The searched winner must
    not lose to the heuristic beyond the ``TUNE_DRIFT_MARGIN`` noise
    margin (default 10%) -- the search space contains the heuristic's own
    blocks, so a regression means the measurement harness lies."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.kernels.matmul import matmul
    from repro.tune import Tuner

    margin = float(os.environ.get("TUNE_DRIFT_MARGIN", "0.10"))
    interpret = jax.default_backend() not in ("tpu", "gpu")
    cfg = get_config("deepseek_moe_16b")
    shapes = (
        ("square", (256, 256, 256)),
        ("ragged", (384, 128, 256)),
        ("moe_expert", (128, min(cfg.moe_d_ff, 512), min(cfg.d_model, 512))),
    )
    tuner = Tuner(reps=3, max_candidates=8, interpret=interpret)

    def best_us(fn, reps: int = 5) -> float:
        # min-of-N, not mean: interpret-mode dispatch has heavy-tailed
        # stragglers that would swamp the 10% gate with pure noise
        fn()
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts) * 1e6

    rows: List[Row] = []
    for label, (m, n, k) in shapes:
        a = jnp.ones((m, k), jnp.bfloat16)
        b = jnp.ones((k, n), jnp.bfloat16)
        entry = tuner.entry_for(m, n, k, dtype="bfloat16")

        def run_default():
            jax.block_until_ready(matmul(a, b, interpret=interpret))

        def run_tuned():
            jax.block_until_ready(matmul(
                a, b, block_m=entry.block_m, block_n=entry.block_n,
                block_k=entry.block_k, order=entry.order,
                interpret=interpret))

        default_us = best_us(run_default)
        tuned_us = best_us(run_tuned)
        speedup = default_us / max(tuned_us, 1e-9)
        rows.append((f"tuned_vs_default_{label}", tuned_us,
                     f"default_us={default_us:.1f};tuned_us={tuned_us:.1f};"
                     f"speedup={speedup:.2f}x;blocks={entry.label};"
                     f"margin={margin:.2f}"))
        if speedup < 1.0 - margin:
            raise RuntimeError(
                f"tuned blocks regressed on {label} ({m}x{n}x{k}): "
                f"{tuned_us:.1f}us vs default {default_us:.1f}us "
                f"(speedup {speedup:.2f}x < {1.0 - margin:.2f}x)")
    return rows


ALL_BENCHES = (
    bench_cannon_solver,
    bench_cannon_comm,
    bench_25d_comm,
    bench_fattree,
    bench_spacebounded,
    bench_hex,
    bench_lowerbound,
    bench_matmul_kernel,
    bench_flash_kernel,
    bench_strategy_choice,
    bench_plan_dispatch,
    bench_overlap_vs_staged,
    bench_fattree_vs_flat,
    bench_tuned_vs_default,
)

# bounded autotuning subset (`benchmarks/run.py --tune-smoke`): interpret-
# mode searches on forced-host CPU; gates the measured-autotuning path
TUNE_BENCHES = (
    bench_tuned_vs_default,
)

# tiny-shape subset for CI (`benchmarks/run.py --smoke`): no big compiles,
# one small 4-device subprocess; surfaces plan-cache, dispatch, and
# overlap-lowering regressions before merge
SMOKE_BENCHES = (
    bench_lowerbound,
    bench_spacebounded,
    bench_strategy_choice,
    bench_plan_dispatch,
    bench_overlap_vs_staged,
    bench_fattree_vs_flat,
)
