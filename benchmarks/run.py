"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout) and mirrors rows into
bench_results.json for the experiment index.

``--smoke`` runs the tiny-shape subset (no subprocess device farms) and
exits nonzero on any bench error -- the CI job that catches plan-cache
and dispatch regressions before merge.

``--conformance`` runs the ``repro.verify`` conformance matrix (strategy x
mesh shape x {square, ragged, batched} x dtype) on forced-host devices
(``CONFORMANCE_DEVICES`` env, default 8): every cell's executed collectives
must match the schedule trace and the analytic cost model exactly.  Exits
nonzero on any non-conforming cell.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

# allow `python benchmarks/run.py` (not just -m benchmarks.run): the import
# below needs the repo root, and the benches need src/ for repro
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def run_conformance() -> int:
    """Forced-host conformance matrix; must run before jax is imported so
    the device-count flag takes effect."""
    devices = int(os.environ.get("CONFORMANCE_DEVICES", "8"))
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={devices}".strip())
    from repro.verify import run_matrix

    rows = run_matrix()
    print("strategy,mesh,case,dtype,ok,words_per_node,error")
    for r in rows:
        mesh = "x".join(str(s) for s in r["mesh"])
        print(f"{r['strategy']},{mesh},{r['case']},{r['dtype']},"
              f"{r['ok']},{r['words_per_node']},{r['error']}", flush=True)
    bad = [r for r in rows if not r["ok"]]
    with open("conformance_results.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# {len(rows)} cells, {len(bad)} non-conforming")
    return 1 if bad else 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--conformance" in argv:
        return run_conformance()

    from benchmarks.paper_benches import ALL_BENCHES, SMOKE_BENCHES

    smoke = "--smoke" in argv
    benches = SMOKE_BENCHES if smoke else ALL_BENCHES
    rows = []
    errors = 0
    print("name,us_per_call,derived")
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}", flush=True)
                rows.append({"name": name, "us_per_call": us, "derived": derived})
        except Exception as e:  # noqa: BLE001 -- report and continue
            print(f"{bench.__name__},NaN,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            rows.append({"name": bench.__name__, "error": str(e)})
            errors += 1
    out = "bench_results_smoke.json" if smoke else "bench_results.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return 1 if (smoke and errors) else 0


if __name__ == "__main__":
    sys.exit(main())
