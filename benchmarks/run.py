"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout; the us field is EMPTY for
derived-only benches, never a fake 0.0) and mirrors rows into
bench_results.json for the experiment index.  Every record and report file
is stamped with ``"schema": 2``.

``--smoke`` runs the tiny-shape subset (no subprocess device farms) under
``repro.obs`` tracing and exits nonzero on any bench error -- the CI job
that catches plan-cache and dispatch regressions before merge.  It writes
two artifacts for upload: ``bench_trace.json`` (Chrome/Perfetto
trace_event) and ``bench_metrics.json`` (flat metrics snapshot).

``--report <metrics.json>`` pretty-prints a metrics snapshot written by
``repro.obs.write_metrics`` (counters, histogram summaries, span counts,
per-strategy collective totals).

``--conformance`` runs the ``repro.verify`` conformance matrix (strategy x
mesh shape x {square, ragged, batched} x dtype) on forced-host devices
(``CONFORMANCE_DEVICES`` env, default 8): every cell's executed collectives
must match the schedule trace and the analytic cost model exactly.  Exits
nonzero on any non-conforming cell.

``--drift [machine_profile.json]`` runs ``repro.verify.drift`` on forced-
host devices (``DRIFT_DEVICES`` env, default 8): obs recorder ==
interceptor == trace on live executions, plus calibrated-ranking stability
against the stored profile when one is given.  When the stored profile
embeds a ``repro.tune`` TuningTable, the tuning leg re-measures each
stored bucket and fails on winners stale beyond the same 10% noise
margin.  Writes drift_report.json; exits nonzero on divergence.

``--tune-smoke`` runs the kernel-autotuning bench subset (bounded
interpret-mode searches; tuned vs default blocks must not regress beyond
the noise margin) and writes bench_results_tune.json.  Exits nonzero on
any bench error -- the CI gate for the measured-autotuning path.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

SCHEMA_VERSION = 2

# allow `python benchmarks/run.py` (not just -m benchmarks.run): the import
# below needs the repo root, and the benches need src/ for repro
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _force_host_devices(env_var: str, default: int) -> None:
    """Set the forced-host device flag; must run before jax is imported."""
    devices = int(os.environ.get(env_var, str(default)))
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={devices}".strip())


def run_conformance() -> int:
    """Forced-host conformance matrix; must run before jax is imported so
    the device-count flag takes effect."""
    _force_host_devices("CONFORMANCE_DEVICES", 8)
    from repro.verify import run_matrix

    rows = run_matrix()
    print("strategy,mesh,case,dtype,overlap,ok,words_per_node,error")
    for r in rows:
        mesh = "x".join(str(s) for s in r["mesh"])
        print(f"{r['strategy']},{mesh},{r['case']},{r['dtype']},"
              f"{r.get('overlap', False)},"
              f"{r['ok']},{r['words_per_node']},{r['error']}", flush=True)
    bad = [r for r in rows if not r["ok"]]
    with open("conformance_results.json", "w") as f:
        json.dump({"schema": SCHEMA_VERSION, "cells": rows}, f, indent=1)
    print(f"# {len(rows)} cells, {len(bad)} non-conforming")
    return 1 if bad else 0


def run_drift(argv) -> int:
    """Forced-host drift check (see repro.verify.drift); flag must precede
    the jax import."""
    _force_host_devices("DRIFT_DEVICES", 8)
    profile_path = None
    i = argv.index("--drift")
    if i + 1 < len(argv) and not argv[i + 1].startswith("-"):
        profile_path = argv[i + 1]
    from repro.verify import check_drift

    report = check_drift(profile_path=profile_path)
    report["schema"] = SCHEMA_VERSION
    print("strategy,mesh,ok,collectives,error")
    for c in report["cells"]:
        mesh = "x".join(str(s) for s in c["mesh"])
        print(f"{c['strategy']},{mesh},{c['ok']},{c['collectives']},"
              f"{c['error']}", flush=True)
    for r in report["ranking"]:
        shape = "x".join(str(s) for s in r["shape"])
        mark = "FLIP" if r["flipped"] else "ok"
        print(f"# ranking {shape}: stored={r['stored_top']} "
              f"fresh={r['fresh_top']} margin={r['margin']:.3f} [{mark}]")
    for r in report.get("tuning", []):
        bucket = "x".join(str(s) for s in r["bucket"])
        mark = "FLIP" if r["flipped"] else "ok"
        print(f"# tuning {r['dtype']} {bucket}: stored={r['stored']} "
              f"fresh={r['fresh']} margin={r['margin']:.3f} [{mark}]")
    with open("drift_report.json", "w") as f:
        json.dump(report, f, indent=1)
    print(f"# drift {'OK' if report['ok'] else 'DIVERGED'} "
          f"({len(report['cells'])} cells, "
          f"{sum(r['flipped'] for r in report['ranking'])} ranking flips, "
          f"{sum(r['flipped'] for r in report.get('tuning', []))} "
          f"tuning flips)")
    return 0 if report["ok"] else 1


def run_report(path: str) -> int:
    """Pretty-print a metrics snapshot written by repro.obs.write_metrics,
    or a bench_results*.json row list written by this driver."""
    with open(path) as f:
        snap = json.load(f)
    if isinstance(snap, list):
        # bench results: rows with possibly-null us_per_call and error rows
        print(f"# bench report: {path} ({len(snap)} rows)")
        for row in snap:
            us = row.get("us_per_call")
            us_field = "-" if us is None else f"{us:.1f}"
            tail = row.get("error") or row.get("derived", "")
            print(f"  {row.get('name', '?')}: {us_field} us  {tail}")
        return 0
    print(f"# metrics report: {path} (schema {snap.get('schema', '?')})")
    metrics = snap.get("metrics", {})
    if metrics:
        print("\n## counters / histograms")
        for name in sorted(metrics):
            v = metrics[name]
            if isinstance(v, dict):  # histogram summary
                print(f"  {name}: n={v['count']} sum={v['sum']:.1f} "
                      f"min={v['min']:.1f} max={v['max']:.1f} "
                      f"mean={v['mean']:.1f}")
            else:
                print(f"  {name}: {v}")
    spans = snap.get("spans", {})
    if spans:
        print("\n## span counts")
        for name in sorted(spans):
            print(f"  {name}: {spans[name]}")
    colls = snap.get("collectives", {})
    if colls:
        print("\n## collectives by strategy")
        for strat in sorted(colls):
            kinds = colls[strat]
            detail = " ".join(
                f"{kind}={c['count']}({c['shard_words']}w)"
                for kind, c in sorted(kinds.items()))
            print(f"  {strat}: {detail}")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--conformance" in argv:
        return run_conformance()
    if "--drift" in argv:
        return run_drift(argv)
    if "--report" in argv:
        i = argv.index("--report")
        if i + 1 >= len(argv):
            print("--report requires a metrics JSON path", file=sys.stderr)
            return 2
        return run_report(argv[i + 1])

    from benchmarks.paper_benches import (ALL_BENCHES, SMOKE_BENCHES,
                                          TUNE_BENCHES)

    smoke = "--smoke" in argv
    tune = "--tune-smoke" in argv
    benches = TUNE_BENCHES if tune else (
        SMOKE_BENCHES if smoke else ALL_BENCHES)

    from repro import obs

    rows = []
    errors = 0
    print("name,us_per_call,derived")
    with obs.observe() as rec:
        for bench in benches:
            try:
                for name, us, derived in bench():
                    # derived-only rows time nothing: empty CSV field, null
                    # JSON value
                    us_field = "" if us is None else f"{us:.1f}"
                    print(f"{name},{us_field},{derived}", flush=True)
                    rows.append({"schema": SCHEMA_VERSION, "name": name,
                                 "us_per_call": us, "derived": derived})
            except Exception as e:  # noqa: BLE001 -- report and continue
                print(f"{bench.__name__},,ERROR:{type(e).__name__}:{e}",
                      flush=True)
                traceback.print_exc(file=sys.stderr)
                rows.append({"schema": SCHEMA_VERSION,
                             "name": bench.__name__, "error": str(e)})
                errors += 1
    out = ("bench_results_tune.json" if tune else
           "bench_results_smoke.json" if smoke else "bench_results.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    if smoke:
        # CI artifacts: Perfetto-loadable trace + flat metrics snapshot
        obs.write_trace("bench_trace.json", rec)
        obs.write_metrics("bench_metrics.json", rec)
        print("# wrote bench_trace.json bench_metrics.json")
    return 1 if ((smoke or tune) and errors) else 0


if __name__ == "__main__":
    sys.exit(main())
