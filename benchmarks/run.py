"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout) and mirrors rows into
bench_results.json for the experiment index.

``--smoke`` runs the tiny-shape subset (no subprocess device farms) and
exits nonzero on any bench error -- the CI job that catches plan-cache
and dispatch regressions before merge.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

# allow `python benchmarks/run.py` (not just -m benchmarks.run): the import
# below needs the repo root, and the benches need src/ for repro
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main(argv=None) -> int:
    from benchmarks.paper_benches import ALL_BENCHES, SMOKE_BENCHES

    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    benches = SMOKE_BENCHES if smoke else ALL_BENCHES
    rows = []
    errors = 0
    print("name,us_per_call,derived")
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}", flush=True)
                rows.append({"name": name, "us_per_call": us, "derived": derived})
        except Exception as e:  # noqa: BLE001 -- report and continue
            print(f"{bench.__name__},NaN,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            rows.append({"name": bench.__name__, "error": str(e)})
            errors += 1
    out = "bench_results_smoke.json" if smoke else "bench_results.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return 1 if (smoke and errors) else 0


if __name__ == "__main__":
    sys.exit(main())
