"""Data pipeline, optimizer, checkpointing, fault tolerance, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, batch_iterator, synth_batch
from repro.optim import adamw
from repro.optim.compress import dequantize_int8, quantize_int8


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
        a = synth_batch(cfg, 7)
        b = synth_batch(cfg, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = synth_batch(cfg, 8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_next_token(self):
        cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2, signal=1.0)
        b = synth_batch(cfg, 0)
        # with signal=1.0 the chain is fully deterministic
        np.testing.assert_array_equal(
            b["labels"][:, :-1], b["tokens"][:, 1:]
        )
        np.testing.assert_array_equal(
            b["labels"], (b["tokens"] * cfg.mult + cfg.add) % cfg.vocab_size
        )

    def test_in_range(self):
        cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=2)
        b = synth_batch(cfg, 0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 128


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        cfg = adamw.AdamWConfig(weight_decay=0.0)
        for _ in range(300):
            grads = {"w": state["master"]["w"]}  # grad of 0.5||w||^2
            state, _ = adamw.step(state, grads, jnp.float32(0.05), cfg)
        assert float(jnp.max(jnp.abs(state["master"]["w"]))) < 0.05

    def test_clipping(self):
        params = {"w": jnp.ones((4,))}
        state = adamw.init(params)
        grads = {"w": jnp.full((4,), 1e6)}
        _, metrics = adamw.step(state, grads, jnp.float32(0.1),
                                adamw.AdamWConfig(clip_norm=1.0))
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip

    def test_schedule(self):
        sched = adamw.warmup_cosine(1.0, 10, 100)
        assert float(sched(jnp.int32(0))) == 0.0
        assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
        assert float(sched(jnp.int32(100))) < 0.2


class TestCompression:
    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256,))
        q, s = quantize_int8(x)
        err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
        assert float(err) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_unbiased(self):
        """With error feedback, the accumulated dequantized signal tracks
        the accumulated true signal."""
        key = jax.random.PRNGKey(1)
        residual = jnp.zeros((64,))
        acc_true = jnp.zeros((64,))
        acc_q = jnp.zeros((64,))
        for i in range(50):
            key, sub = jax.random.split(key)
            g = jax.random.normal(sub, (64,)) * 0.1
            acc_true += g
            x = g + residual
            q, s = quantize_int8(x)
            deq = dequantize_int8(q, s)
            residual = x - deq
            acc_q += deq
        drift = float(jnp.max(jnp.abs(acc_q + residual - acc_true)))
        assert drift < 1e-4


class TestCheckpoint:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.float32(3.5), "d": jnp.ones((4,), jnp.bfloat16)}}
        with tempfile.TemporaryDirectory() as td:
            store.save(td, 5, tree)
            assert store.latest_step(td) == 5
            step, out = store.restore(td, tree)
        assert step == 5
        for k in ("a",):
            np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))
        assert out["b"]["d"].dtype == jnp.bfloat16

    def test_latest_pointer_and_overwrite(self):
        tree = {"x": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as td:
            store.save(td, 1, tree)
            store.save(td, 2, tree)
            store.save(td, 2, {"x": jnp.ones((2,))})  # idempotent re-save
            step, out = store.restore(td, tree)
            assert step == 2
            np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(2))

    def test_async_writer(self):
        tree = {"x": jnp.arange(10)}
        with tempfile.TemporaryDirectory() as td:
            w = store.AsyncWriter()
            w.save(td, 3, tree)
            w.wait()
            assert store.latest_step(td) == 3


class TestTrainerFaultTolerance:
    def test_restart_and_loss_decreases(self):
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.runtime.train import Trainer, TrainConfig

        cfg = get_smoke_config("llama3.2-1b")
        model = build_model(cfg)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        with tempfile.TemporaryDirectory() as td:
            tc = TrainConfig(steps=24, lr=1e-3, warmup=4, ckpt_dir=td,
                             ckpt_every=8, log_every=8, fail_at_step=13)
            out = Trainer(model, tc).fit(jax.random.PRNGKey(0), batch_iterator(dc))
        assert out["restarts"] == 1
        losses = [h["loss"] for h in out["history"]]
        assert losses[-1] < losses[0]


class TestServe:
    def test_generate_greedy_deterministic(self):
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.runtime.serve import ServeConfig, generate

        cfg = get_smoke_config("llama3.2-1b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
        sc = ServeConfig(max_new_tokens=6, max_seq=32)
        a = generate(model, params, prompts, sc)
        b = generate(model, params, prompts, sc)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (2, 9)
