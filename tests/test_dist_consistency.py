"""Solver <-> executor consistency.

The contract of repro.dist.cannon: the ppermute program it runs IS the
solver's solution -- shift vectors equal the movement homomorphisms, the
skew equals the schedule's initial placement, and the lowered (src, dst)
pairs are exactly the mu translations on the flattened torus.  Plus cost
model sanity: estimates are monotone in problem size.
"""
import pytest

from repro.core.schedule import cannon_schedule
from repro.dist.api import applicable_strategies, estimate
from repro.dist.cannon import executed_shift_vectors, lowered_plan


@pytest.mark.parametrize("q", [2, 3, 4, 5, 8])
def test_cannon_executed_shifts_equal_solver_movements(q):
    sched = cannon_schedule(q)
    assert executed_shift_vectors(q) == sched.movements()
    # the lowered one-step ppermute pairs are exactly the mu translation
    for var in ("A", "B", "C"):
        mu = sched.movement(var)
        for src, dst in sched.movement_perm(var):
            sx, sy = divmod(src, q)
            dx, dy = divmod(dst, q)
            assert ((dx - sx) % q, (dy - sy) % q) == mu


@pytest.mark.parametrize("q", [2, 3, 4])
def test_cannon_skew_is_schedule_placement(q):
    sched = cannon_schedule(q)
    pl = sched.placement("A")
    plb = sched.placement("B")
    for r in range(q):
        for s in range(q):
            # classic skews: A_ij -> P_{i, j-i}, B_jk -> P_{j-k, k}
            assert tuple(pl[r, s]) == (r, (s - r) % q)
            assert tuple(plb[r, s]) == ((r - s) % q, s)
    plan = lowered_plan(sched)
    # Cannon's C is stationary and already in canonical layout: the
    # collection perm must be elided (empty) so no collective is emitted
    assert plan["collect_C"] == []
    # A's skew perm maps canonical (r, s) to placement (r, (s-r) % q)
    for src, dst in plan["skew"]["A"]:
        r, s = divmod(src, q)
        assert dst == r * q + (s - r) % q


@pytest.mark.parametrize("strategy", ["xla_ag", "ring_ag", "xla_rs",
                                      "ring_rs", "cannon", "summa",
                                      "cannon25d"])
def test_estimate_monotone_in_problem_size(strategy):
    tp = 16
    base = estimate(strategy, 1024, 1024, 1024, tp).total_s
    assert base > 0
    for grow in ((2048, 1024, 1024), (1024, 2048, 1024), (1024, 1024, 2048)):
        assert estimate(strategy, *grow, tp).total_s >= base


def test_overlapped_never_slower_and_applicability():
    m, n, k, tp = 8192, 4096, 4096, 16
    for plain, ring in (("xla_ag", "ring_ag"), ("xla_rs", "ring_rs")):
        assert estimate(ring, m, n, k, tp).total_s <= \
            estimate(plain, m, n, k, tp).total_s + 1e-12
    assert applicable_strategies(1) == ("local",)
    assert "cannon" in applicable_strategies(16)
    assert "cannon25d" in applicable_strategies(8)  # 8 = 2^2 * 2
