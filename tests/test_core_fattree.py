"""Direct unit tests for core.fattree (Sec. 4.2 wreath-product schedules).

Previously exercised only indirectly via test_core_groups; these pin the
schedule's validity, position functions, hop/link accounting, and boundary
sizes level by level.
"""
import pytest

from repro.core.fattree import (FatTreeSchedule, tree_exchange_mask,
                                tree_exchange_perm)


@pytest.mark.parametrize("d", [1, 2, 3])
class TestScheduleValidity:
    def test_boundary_sizes(self, d):
        ft = FatTreeSchedule(d=d)
        assert ft.n == 2 ** d
        assert ft.num_procs == 4 ** d
        assert ft.num_steps == 2 ** d
        # n^3 instructions fill the (proc, time) grid exactly
        assert ft.n ** 3 == ft.num_procs * ft.num_steps

    def test_f_is_a_bijection_onto_proc_time(self, d):
        ft = FatTreeSchedule(d=d)
        n = ft.n
        cells = {ft.f(i, j, k)
                 for i in range(n) for j in range(n) for k in range(n)}
        assert len(cells) == n ** 3
        assert ft.validate()

    def test_positions_consistent_with_f(self, d):
        """pos_A/pos_B invert f's time bits: the processor executing
        (i, j, k) at step t holds A_ij and B_jk at that step."""
        ft = FatTreeSchedule(d=d)
        n = ft.n
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    proc, time = ft.f(i, j, k)
                    assert ft.pos_A(i, j, time) == proc
                    assert ft.pos_B(j, k, time) == proc
                    assert ft.pos_C(k, i) == proc

    def test_c_layout_is_a_bijection(self, d):
        """C stationary, one element per processor (3-words memory)."""
        ft = FatTreeSchedule(d=d)
        n = ft.n
        procs = {ft.pos_C(k, i) for k in range(n) for i in range(n)}
        assert procs == set(range(ft.num_procs))


class TestHopCounts:
    def test_base_case_fig11_traffic(self):
        """d=1 (Fig. 11): 4 words of A over the top link (8 words x links
        counting both transits), 16 words x links over the leaf level."""
        ft = FatTreeSchedule(d=1)
        assert ft.link_traffic() == {1: 16, 2: 8}
        assert ft.top_level_words() == 4 == ft.n ** 2

    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_top_level_words_is_n_squared(self, d):
        """The paper's Sec.-4.2 claim: only A crosses the root, n^2 words
        over the whole run."""
        ft = FatTreeSchedule(d=d)
        assert ft.top_level_words() == ft.n ** 2

    @pytest.mark.parametrize("d", [2, 3])
    def test_traffic_decreases_up_the_tree(self, d):
        """Words x links shrink strictly toward the root -- the recursion
        localizes most movement to the lower levels."""
        traffic = FatTreeSchedule(d=d).link_traffic()
        levels = sorted(traffic)
        for lo, hi in zip(levels, levels[1:]):
            assert traffic[lo] > traffic[hi] > 0

    def test_a_moves_every_step_b_moves_low_bits(self):
        """Level structure of the base case: A's position flips its high
        bit every step, B its low bit."""
        ft = FatTreeSchedule(d=1)
        for a in range(2):
            for b in range(2):
                pa = [ft.pos_A(a, b, t) for t in range(2)]
                pb = [ft.pos_B(a, b, t) for t in range(2)]
                assert pa[0] ^ pa[1] == 0b10  # top-level crossing
                assert pb[0] ^ pb[1] == 0b01  # leaf-level crossing

    def test_base_case_word_pins(self):
        """Direct pin of the paper's Fig.-11 constants in word (not
        words x links) units: 8 words cross the leaf links, 4 = n^2 the
        top link -- the dead-conditional regression guard."""
        ft = FatTreeSchedule(d=1)
        assert ft.level_words(1) == 8
        assert ft.level_words(2) == 4

    def test_traffic_sweep_is_cached(self):
        """``link_traffic``/``level_words``/``top_level_words`` share one
        cached sweep, and the public dict is a defensive copy."""
        ft = FatTreeSchedule(d=2)
        assert ft._link_traffic is ft._link_traffic
        public = ft.link_traffic()
        assert public == ft._link_traffic and public is not ft._link_traffic
        public[1] = -1
        assert ft.link_traffic()[1] != -1

    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_link_traffic_matches_trace_oracle(self, d):
        """Cross-check against the independent oracle: the verify tracer
        replays positions into events and buckets them by level with its
        own accounting -- both derivations must agree exactly."""
        from repro.verify import fattree_level_words, trace_fattree

        ft = FatTreeSchedule(d=d)
        assert ft.link_traffic() == fattree_level_words(trace_fattree(ft), d)


class TestExchangeMasks:
    """The Gray-walk exchange helpers driving the hierarchical lowering."""

    @pytest.mark.parametrize("s", [2, 4, 8, 16])
    def test_masks_are_gray_and_root_crossed_once(self, s):
        masks = [tree_exchange_mask(t) for t in range(s - 1)]
        # each mask is 2^(b+1) - 1: the Gray-code increment form
        assert all(m & (m + 1) == 0 and m > 0 for m in masks)
        # the root (top bit of the pod index) is crossed exactly once
        assert sum(1 for m in masks if m >> (s.bit_length() - 2)) == 1
        assert masks[s // 2 - 1] == s - 1

    @pytest.mark.parametrize("s", [2, 4, 8])
    def test_perms_are_involutions_covering_all_slabs(self, s):
        for t in range(s - 1):
            perm = dict(tree_exchange_perm(s, t))
            assert sorted(perm) == list(range(s))
            assert all(perm[perm[d]] == d and perm[d] != d for d in perm)
        # the walk j = p ^ t visits every slab on every pod
        for p in range(s):
            assert {p ^ t for t in range(s)} == set(range(s))
