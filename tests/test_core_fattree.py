"""Direct unit tests for core.fattree (Sec. 4.2 wreath-product schedules).

Previously exercised only indirectly via test_core_groups; these pin the
schedule's validity, position functions, hop/link accounting, and boundary
sizes level by level.
"""
import pytest

from repro.core.fattree import FatTreeSchedule


@pytest.mark.parametrize("d", [1, 2, 3])
class TestScheduleValidity:
    def test_boundary_sizes(self, d):
        ft = FatTreeSchedule(d=d)
        assert ft.n == 2 ** d
        assert ft.num_procs == 4 ** d
        assert ft.num_steps == 2 ** d
        # n^3 instructions fill the (proc, time) grid exactly
        assert ft.n ** 3 == ft.num_procs * ft.num_steps

    def test_f_is_a_bijection_onto_proc_time(self, d):
        ft = FatTreeSchedule(d=d)
        n = ft.n
        cells = {ft.f(i, j, k)
                 for i in range(n) for j in range(n) for k in range(n)}
        assert len(cells) == n ** 3
        assert ft.validate()

    def test_positions_consistent_with_f(self, d):
        """pos_A/pos_B invert f's time bits: the processor executing
        (i, j, k) at step t holds A_ij and B_jk at that step."""
        ft = FatTreeSchedule(d=d)
        n = ft.n
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    proc, time = ft.f(i, j, k)
                    assert ft.pos_A(i, j, time) == proc
                    assert ft.pos_B(j, k, time) == proc
                    assert ft.pos_C(k, i) == proc

    def test_c_layout_is_a_bijection(self, d):
        """C stationary, one element per processor (3-words memory)."""
        ft = FatTreeSchedule(d=d)
        n = ft.n
        procs = {ft.pos_C(k, i) for k in range(n) for i in range(n)}
        assert procs == set(range(ft.num_procs))


class TestHopCounts:
    def test_base_case_fig11_traffic(self):
        """d=1 (Fig. 11): 4 words of A over the top link (8 words x links
        counting both transits), 16 words x links over the leaf level."""
        ft = FatTreeSchedule(d=1)
        assert ft.link_traffic() == {1: 16, 2: 8}
        assert ft.top_level_words() == 4 == ft.n ** 2

    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_top_level_words_is_n_squared(self, d):
        """The paper's Sec.-4.2 claim: only A crosses the root, n^2 words
        over the whole run."""
        ft = FatTreeSchedule(d=d)
        assert ft.top_level_words() == ft.n ** 2

    @pytest.mark.parametrize("d", [2, 3])
    def test_traffic_decreases_up_the_tree(self, d):
        """Words x links shrink strictly toward the root -- the recursion
        localizes most movement to the lower levels."""
        traffic = FatTreeSchedule(d=d).link_traffic()
        levels = sorted(traffic)
        for lo, hi in zip(levels, levels[1:]):
            assert traffic[lo] > traffic[hi] > 0

    def test_a_moves_every_step_b_moves_low_bits(self):
        """Level structure of the base case: A's position flips its high
        bit every step, B its low bit."""
        ft = FatTreeSchedule(d=1)
        for a in range(2):
            for b in range(2):
                pa = [ft.pos_A(a, b, t) for t in range(2)]
                pb = [ft.pos_B(a, b, t) for t in range(2)]
                assert pa[0] ^ pa[1] == 0b10  # top-level crossing
                assert pb[0] ^ pb[1] == 0b01  # leaf-level crossing
