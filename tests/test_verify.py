"""Pure (no-device) tests of the repro.verify trace/conformance subsystem.

The measured leg (interceptor vs. real shard_map programs) lives in
tests/test_conformance.py's subprocess; everything here runs on fake
planner meshes and the algebra alone.
"""
import dataclasses
import math
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (cannon_schedule, movement_equations_hold,
                        perm_is_bijection, perm_link_words, perm_translation)
from repro.core.cost import torus_schedule_cost
from repro.core.fattree import FatTreeSchedule
from repro.core.hexarray import HexSchedule
from repro.plan import build_plan
from repro.verify import (ConformanceError, check, compare_records,
                          fattree_level_words, predicted_words_per_device,
                          trace_fattree, trace_hex, trace_plan)
from repro.verify.trace import CollectiveRecord, padded_dims


def fake_mesh(sizes, names):
    total = math.prod(sizes)
    return SimpleNamespace(
        axis_names=tuple(names),
        shape=dict(zip(names, sizes)),
        size=total,
        devices=np.array([SimpleNamespace(id=i, platform="cpu")
                          for i in range(total)]),
    )


STRATEGY_MESHES = [
    ("cannon", (3, 3), ("x", "y")),
    ("summa", (2, 4), ("x", "y")),
    ("pod25d", (4,), ("pod",)),
    ("pod25d", (2, 2, 2), ("pod", "x", "y")),
    ("cannon25d", (2, 2, 2), ("pod", "x", "y")),
    ("ring_ag", (4,), ("t",)),
    ("ring_rs", (2, 2), ("x", "y")),
]


# ---------------------------------------------------------------------------
# core predicates
# ---------------------------------------------------------------------------


def test_perm_predicates():
    q = 3
    sched = cannon_schedule(q)
    step_a = sched.movement_perm("A")
    assert perm_is_bijection(step_a, q * q)
    assert perm_translation(step_a, q) == sched.movement("A")
    # a swapped destination is neither a translation nor (here) a bijection
    bad = list(step_a)
    bad[0] = (bad[0][0], bad[1][1])
    assert perm_translation(bad, q) is None
    assert not perm_is_bijection(bad, q * q)
    assert movement_equations_hold(sched)


def test_perm_link_words_matches_hops():
    q = 4
    sched = cannon_schedule(q)
    # one-hop translation over q^2 blocks of 5 words: q^2 * 5 link-words
    assert perm_link_words(sched.movement_perm("A"), q, 5.0) == q * q * 5.0
    # stationary C: zero link-words
    assert perm_link_words(sched.movement_perm("C"), q, 5.0) == 0.0


# ---------------------------------------------------------------------------
# trace == cost model on every strategy (the no-device legs of check)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,shape,names", STRATEGY_MESHES)
def test_check_passes_on_planned_strategies(strategy, shape, names):
    plan = build_plan(24, 24, 24, mesh=fake_mesh(shape, names),
                      strategy=strategy)
    rep = check(plan)
    assert rep.words_per_node == pytest.approx(
        predicted_words_per_device(plan))
    assert rep.strategy == strategy


@pytest.mark.parametrize("case", [(13, 7, 11, ()), (5, 8, 12, (3,))])
@pytest.mark.parametrize("strategy,shape,names", STRATEGY_MESHES)
def test_check_passes_ragged_and_batched(strategy, shape, names, case):
    m, n, k, batch = case
    plan = build_plan(m, n, k, mesh=fake_mesh(shape, names),
                      strategy=strategy, batch=batch)
    check(plan)


def test_trace_cannon_structure():
    q = 3
    plan = build_plan(30, 30, 30, mesh=fake_mesh((q, q), ("x", "y")),
                      strategy="cannon")
    tr = trace_plan(plan)
    # 2 skews + (q-1) steps x {A, B} (C stationary), no collection
    assert tr.counts() == {"ppermute": 2 + 2 * (q - 1)}
    phases = [r.phase for r in tr.records]
    assert phases.count("placement") == 2
    assert phases.count("movement") == 2 * (q - 1)
    assert phases.count("collection") == 0
    # movement words: A and B move one block per node per step
    blk = (30 // q) * (30 // q)
    assert tr.movement_words() == 2 * (q - 1) * blk * q * q


def test_trace_link_words_equal_paper_cost():
    """The trace's link-word count IS torus_schedule_cost's word count --
    the Sec.-2.4 functional evaluated on the executed program."""
    q, n = 4, 32
    plan = build_plan(n, n, n, mesh=fake_mesh((q, q), ("x", "y")),
                      strategy="cannon")
    tr = trace_plan(plan)
    assert tr.link_words(q) == torus_schedule_cost(cannon_schedule(q),
                                                   n).words_total


def test_padded_dims_fold_batch_and_ragged():
    plan = build_plan(5, 7, 11, mesh=fake_mesh((3, 3), ("x", "y")),
                      strategy="cannon", batch=(4,))
    assert padded_dims(plan) == (21, 9, 12)  # 20 rows -> 21, 7 -> 9, 11 -> 12


# ---------------------------------------------------------------------------
# mutations are caught
# ---------------------------------------------------------------------------


def _cannon_plan(q=3, n=24):
    return build_plan(n, n, n, mesh=fake_mesh((q, q), ("x", "y")),
                      strategy="cannon")


def test_wrong_permutation_mutation_caught():
    plan = _cannon_plan()
    pairs = list(plan.torus.step_a)
    pairs[0], pairs[1] = (pairs[0][0], pairs[1][1]), (pairs[1][0], pairs[0][1])
    bad = dataclasses.replace(
        plan, torus=dataclasses.replace(plan.torus, step_a=tuple(pairs)))
    with pytest.raises(ConformanceError):
        check(bad)


def test_wrong_translation_mutation_caught():
    """Still a bijective translation -- but not the schedule's mu."""
    plan = _cannon_plan()
    q = plan.torus.q
    wrong = tuple((x * q + y, ((x + 1) % q) * q + y)
                  for x in range(q) for y in range(q))
    bad = dataclasses.replace(
        plan, torus=dataclasses.replace(plan.torus, step_b=wrong))
    with pytest.raises(ConformanceError):
        check(bad)


def test_compare_records_catches_divergence():
    plan = _cannon_plan()
    recs = list(trace_plan(plan).records)
    tampered = recs[:-1] + [dataclasses.replace(recs[-1],
                                                shard_words=recs[-1].shard_words + 1)]
    with pytest.raises(ConformanceError):
        compare_records(recs, tampered)
    compare_records(recs, list(reversed(recs)))  # order-insensitive


def test_collective_record_word_conventions():
    pp = CollectiveRecord("ppermute", 4, 10, ((0, 1), (1, 2), (2, 3), (3, 0)))
    assert pp.words_total(4) == 40          # one shard per pair
    assert pp.words_total(8) == 80          # two independent ring copies
    ag = CollectiveRecord("all_gather", 4, 10)
    assert ag.words_total(4) == 120         # each device receives g-1 shards
    ps = CollectiveRecord("psum", 4, 10)
    assert ps.words_total(4) == 60          # 2(g-1) shards per group


# ---------------------------------------------------------------------------
# machine-model traces: fat-tree and hex array
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [1, 2])
def test_fattree_trace_matches_link_traffic_oracle(d):
    ft = FatTreeSchedule(d=d)
    tr = trace_fattree(ft)
    assert fattree_level_words(tr, d) == ft.link_traffic()
    # the paper's top-link claim through the trace: n^2 words of A
    top = fattree_level_words(tr, d)[2 * d] // 2
    assert top == ft.n ** 2 == ft.top_level_words()


def test_hex_trace_one_link_per_step():
    hs = HexSchedule(q=4)
    tr = trace_hex(hs)
    # every element of every stream moves q-1 times
    assert len(tr.events) == 3 * hs.q * hs.q * (hs.q - 1)
    assert tr.words_total() == len(tr.events)
