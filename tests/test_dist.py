"""Distributed matmul strategies vs references on 8 fake devices.

Runs in a subprocess so the main pytest process keeps the default 1-device
view (the dry-run owns the 512-device configuration)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist import (cannon_matmul, summa_matmul, pod25d_matmul,
                        ring_ag_matmul, ring_rs_matmul)

devs = np.array(jax.devices())
mesh22 = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
M, K, N = 32, 24, 16
a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
ref = a @ b
tol = 2e-5

c = jax.jit(functools.partial(cannon_matmul, mesh=mesh22, axis_x="x", axis_y="y"))(a, b)
assert float(jnp.max(jnp.abs(c - ref))) < tol, "cannon"

c = jax.jit(functools.partial(summa_matmul, mesh=mesh22, axis_x="x", axis_y="y"))(a, b)
assert float(jnp.max(jnp.abs(c - ref))) < tol, "summa"

mesh_pod = jax.make_mesh((2,), ("pod",), devices=devs[:2])
c = jax.jit(functools.partial(pod25d_matmul, mesh=mesh_pod, pod_axis="pod"))(a, b)
assert float(jnp.max(jnp.abs(c - ref))) < tol, "pod25d"

mesh_r = jax.make_mesh((4,), ("t",), devices=devs[:4])
S, D, F = 16, 8, 12
x = jax.random.normal(jax.random.PRNGKey(2), (S, D), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(3), (D, F), jnp.float32)
ag = jax.jit(jax.shard_map(lambda xl, wl: ring_ag_matmul(xl, wl, "t"),
    mesh=mesh_r, in_specs=(P("t", None), P(None, "t")), out_specs=P(None, "t")))(x, w)
assert float(jnp.max(jnp.abs(ag - x @ w))) < tol, "ring_ag"

y = jax.random.normal(jax.random.PRNGKey(4), (S, F), jnp.float32)
w2 = jax.random.normal(jax.random.PRNGKey(5), (F, D), jnp.float32)
rs = jax.jit(jax.shard_map(lambda yl, wl: ring_rs_matmul(yl, wl, "t"),
    mesh=mesh_r, in_specs=(P(None, "t"), P("t", None)), out_specs=P("t", None)))(y, w2)
assert float(jnp.max(jnp.abs(rs - y @ w2))) < tol, "ring_rs"

# batched (3D) ring matmul, as used by the transformer layers
xb = jax.random.normal(jax.random.PRNGKey(6), (2, S, D), jnp.float32)
agb = jax.jit(jax.shard_map(lambda xl, wl: ring_ag_matmul(xl, wl, "t"),
    mesh=mesh_r, in_specs=(P(None, "t", None), P(None, "t")),
    out_specs=P(None, None, "t")))(xb, w)
assert float(jnp.max(jnp.abs(agb - xb @ w))) < tol, "ring_ag_batched"

# 3-axis production-style mesh: 2.5D over pod composed with in-layer summa
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "x", "y"), devices=devs[:8])
c = jax.jit(functools.partial(pod25d_matmul, mesh=mesh3, pod_axis="pod"))(a, b)
assert float(jnp.max(jnp.abs(c - ref))) < tol, "pod25d_3axis"

print("DIST_SELFTEST_OK")
"""


@pytest.mark.timeout(600)
def test_distributed_strategies_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=590,
    )
    assert "DIST_SELFTEST_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
    )


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
