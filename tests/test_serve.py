"""Serving harness tests: decode determinism, left-padding invariance,
bucket routing, warmup cache pinning, sweep-JSON schema.

The plan-routed path (mesh-dependent) runs in a subprocess on forced-host
devices, like tests/test_plan_exec.py; everything else runs in-process on
the 1-device view.  The routed-vs-unrouted bitwise comparison uses an
fp32 model: split-K schedules legitimately reorder the fp32 accumulation,
and in bf16 that noise (~1 ulp per matmul) can flip greedy argmax ties --
fp32 keeps the top-1 margin orders of magnitude above it.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.runtime.serve import ServeConfig, batch_requests, generate
from repro.serve import Bucket, Server, bucket_grid, route, warmup


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# --- ServeConfig validation (edge cases that used to slip through) ---------


def test_serveconfig_rejects_bad_fields():
    with pytest.raises(ValueError, match="max_new_tokens"):
        ServeConfig(max_new_tokens=-1)
    with pytest.raises(ValueError, match="max_seq"):
        ServeConfig(max_seq=0)
    with pytest.raises(ValueError, match="temperature"):
        ServeConfig(temperature=-0.5)


def test_generate_max_new_zero_appends_nothing(llama):
    """max_new_tokens=0 must return the prompts unchanged -- the seed
    version still appended one sampled token."""
    _, model, params = llama
    prompts = np.array([[5, 6, 7, 8]], np.int32)
    out = generate(model, params, prompts,
                   ServeConfig(max_new_tokens=0, max_seq=32))
    assert out.shape == (1, 4)
    assert np.array_equal(out, prompts)


def test_generate_cache_overrun_raises(llama):
    """prompt + max_new_tokens > max_seq used to silently overrun the KV
    cache; now it's a ValueError before any compute."""
    _, model, params = llama
    prompts = np.array([[1] * 30], np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        generate(model, params, prompts,
                 ServeConfig(max_new_tokens=8, max_seq=32))


# --- batch_requests --------------------------------------------------------


def test_batch_requests_empty_list():
    """Used to raise a bare ValueError from max() on an empty sequence."""
    batch, lens = batch_requests([])
    assert batch.shape == (0, 0) and lens.shape == (0,)


def test_generate_empty_batch(llama):
    _, model, params = llama
    batch, _ = batch_requests([])
    out = generate(model, params, batch, ServeConfig(max_new_tokens=4))
    assert out.shape == (0, 0)


def test_batch_requests_shapes_and_lens():
    batch, lens = batch_requests([[1, 2, 3], [7]], pad_id=9)
    assert batch.tolist() == [[1, 2, 3], [9, 9, 7]]
    assert lens.tolist() == [3, 1]


def test_batch_requests_pad_to():
    batch, lens = batch_requests([[1, 2]], pad_to=5)
    assert batch.tolist() == [[0, 0, 0, 1, 2]] and lens.tolist() == [2]
    with pytest.raises(ValueError, match="pad_to"):
        batch_requests([[1, 2, 3]], pad_to=2)


def test_batch_requests_rejects_empty_prompt():
    with pytest.raises(ValueError, match="empty"):
        batch_requests([[1, 2], []])


# --- bucket router ---------------------------------------------------------


def test_bucket_validation_and_grid():
    with pytest.raises(ValueError):
        Bucket(0, 8)
    grid = bucket_grid([4, 2], [32, 16])
    assert [b.label for b in grid] == ["2x16", "2x32", "4x16", "4x32"]


def test_route_picks_smallest_fitting():
    buckets = bucket_grid([2, 4], [16, 32])
    assert route(2, 10, buckets) == Bucket(2, 16)
    assert route(3, 10, buckets) == Bucket(4, 16)
    assert route(2, 20, buckets) == Bucket(2, 32)
    assert route(5, 10, buckets) is None      # batch too large
    assert route(2, 40, buckets) is None      # prompt too long


def test_server_rejects_bucket_overrunning_cache(llama):
    _, model, params = llama
    with pytest.raises(ValueError, match="max_seq"):
        Server(model, params, ServeConfig(max_new_tokens=8, max_seq=16),
               buckets=[(2, 16)])


# --- decode determinism ----------------------------------------------------


def test_greedy_determinism_across_runs_and_batch_order(llama):
    _, model, params = llama
    cfg = ServeConfig(max_new_tokens=5, max_seq=32)
    prompts = [[5, 6, 7], [9, 2, 3, 4]]
    batch, lens = batch_requests(prompts)
    a = generate(model, params, batch, cfg, lens=lens)
    b = generate(model, params, batch, cfg, lens=lens)
    assert np.array_equal(a, b)
    # reversed batch order: same per-request tokens, permuted rows
    rbatch, rlens = batch_requests(prompts[::-1])
    r = generate(model, params, rbatch, cfg, lens=rlens)
    for i, p in enumerate(prompts):
        fwd = a[i, batch.shape[1] - lens[i]:]
        rev = r[1 - i, rbatch.shape[1] - rlens[1 - i]:]
        assert np.array_equal(fwd, rev), f"request {i} depends on batch order"


def test_temperature_sampling_reproducible_under_fixed_key(llama):
    _, model, params = llama
    cfg = ServeConfig(max_new_tokens=6, max_seq=32, temperature=0.8)
    prompts = np.array([[5, 6, 7], [9, 2, 3]], np.int32)
    key = jax.random.PRNGKey(42)
    a = generate(model, params, prompts, cfg, key=key)
    b = generate(model, params, prompts, cfg, key=key)
    assert np.array_equal(a, b)
    c = generate(model, params, prompts, cfg, key=jax.random.PRNGKey(7))
    assert a.shape == c.shape


# --- left-padding invariance ----------------------------------------------


@pytest.mark.parametrize("arch", ["llama3_2_1b", "minicpm3_4b"])
def test_left_pad_invariance(arch):
    """A prompt decoded alone emits the same greedy tokens as when it is
    left-padded into a mixed-length batch with per-row offsets (GQA and
    MLA attention paths)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_new_tokens=4, max_seq=32)
    prompts = [[5, 6, 7], [9, 2, 3, 4, 1, 8]]
    batch, lens = batch_requests(prompts)
    padded = generate(model, params, batch, scfg, lens=lens)
    for i, p in enumerate(prompts):
        alone = generate(model, params, np.asarray([p], np.int32), scfg)
        row = padded[i, batch.shape[1] - lens[i]:]
        assert np.array_equal(alone[0], row), (
            f"{arch} request {i}: alone {alone[0].tolist()} != "
            f"padded {row.tolist()}")


def test_server_bucket_invariance_and_trimming(llama):
    """Routing through a bucket (seq left-pad + dummy batch rows) must not
    change any request's greedy tokens, and padding must be stripped from
    the returned sequences."""
    _, model, params = llama
    scfg = ServeConfig(max_new_tokens=4, max_seq=32)
    srv = Server(model, params, scfg, buckets=[(4, 8)])
    srv.warmup()
    prompts = [[5, 6, 7], [9, 2, 3, 4, 1]]
    res = srv.generate(prompts)
    assert res.bucket == "4x8"
    assert len(res.sequences) == 2           # dummy rows trimmed
    for i, p in enumerate(prompts):
        alone = generate(model, params, np.asarray([p], np.int32), scfg)
        assert res.sequences[i] == alone[0].tolist()
        assert res.new_tokens[i] == alone[0, len(p):].tolist()


# --- Server edge behavior --------------------------------------------------


def test_server_empty_cold_and_null_latency(llama):
    _, model, params = llama
    srv = Server(model, params, ServeConfig(max_new_tokens=2, max_seq=64),
                 buckets=[(2, 8)])
    srv.warmup()
    assert srv.generate([]).sequences == []
    cold = srv.generate([[1] * 20])          # longer than any bucket seq
    assert cold.bucket is None and len(cold.new_tokens[0]) == 2
    zero = Server(model, params, ServeConfig(max_new_tokens=0, max_seq=64),
                  buckets=[(2, 8)])
    r0 = zero.generate([[5, 6, 7]])
    assert r0.new_tokens == [[]]
    assert r0.latency_quantiles_ms() == {"p50_ms": None, "p99_ms": None}


def test_warmup_helper_returns_warm_server(llama):
    _, model, params = llama
    srv = warmup(model, params, ServeConfig(max_new_tokens=2, max_seq=64),
                 buckets=[(2, 8)])
    assert "2x8" in srv.warmup_report
    res = srv.generate([[4, 5]])
    assert res.bucket == "2x8" and len(res.new_tokens[0]) == 2


# --- sweep JSON schema + report -------------------------------------------


def _synthetic_sweep():
    cell = {
        "mesh": "2x2", "bucket": "4x16", "strategy": "auto", "ok": True,
        "routed": True, "plans": 8, "warmup_s": 1.0, "tokens_per_s": 100.0,
        "tokens_per_s_per_device": 12.5, "ttft_ms": 9.5,
        "p50_ms": None, "p99_ms": None,   # 1-token run: no timed steps
        "cache_hit_rate": 1.0, "match_baseline": True, "error": None,
    }
    bad = {"mesh": "1x4", "bucket": "4x16", "strategy": "cannon",
           "ok": False, "error": "ValueError: cannon needs a square mesh"}
    return {
        "schema": "repro.serve_sweep/v1", "arch": "llama3.2-1b-smoke",
        "created_unix": 1754600000,
        "config": {"max_new_tokens": 1, "max_seq": 64, "devices": 8,
                   "buckets": ["4x16"]},
        "cells": [cell, bad],
    }


def test_sweep_schema_roundtrip_and_null_latency_rendering(tmp_path):
    from repro.launch.report import serve_sweep_table

    data = _synthetic_sweep()
    path = tmp_path / "sweep.json"
    path.write_text(json.dumps(data))
    back = json.loads(path.read_text())
    assert back == data
    table = serve_sweep_table(back)
    row = [ln for ln in table.splitlines() if "4x16" in ln and "auto" in ln][0]
    cols = [c.strip() for c in row.split("|")]
    assert cols[8] == "-" and cols[9] == "-"      # null p50/p99 render as -
    assert "100.000" in row and "1.000" in row
    err_row = [ln for ln in table.splitlines() if "ERR" in ln][0]
    assert "square mesh" in err_row


def test_sweep_report_cli(tmp_path):
    path = tmp_path / "sweep.json"
    path.write_text(json.dumps(_synthetic_sweep()))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, os.path.join(_root(), "benchmarks", "serve_sweep.py"),
         "--report", str(path)],
        capture_output=True, text=True, env=env, timeout=120)
    assert res.returncode == 0, res.stderr
    assert "| mesh | bucket |" in res.stdout and "| - | - |" in res.stdout


def test_sweep_baseline_diff_margin():
    sys.path.insert(0, os.path.join(_root(), "benchmarks"))
    try:
        import serve_sweep
    finally:
        sys.path.pop(0)
    now, prev = _synthetic_sweep(), _synthetic_sweep()
    prev["cells"][0]["tokens_per_s"] = 200.0
    regressions, lines = serve_sweep.diff_baseline(now, prev, margin=0.25)
    assert len(regressions) == 1 and "REGRESSION" in lines[0]
    regressions, _ = serve_sweep.diff_baseline(now, prev, margin=0.60)
    assert regressions == []


# --- plan-routed serving on forced-host devices (subprocess) ---------------

_ROUTED_SCRIPT = r"""
import dataclasses, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro import obs
from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.plan import cache_info
from repro.runtime.serve import ServeConfig, batch_requests, generate
from repro.serve import Server, warmup

devs = jax.devices()
mesh = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"), dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
scfg = ServeConfig(max_new_tokens=5, max_seq=64)
prompts = [[5, 6, 7], [9, 2, 3, 4, 1]]

# unrouted baseline through the same Server machinery
base = Server(model, params, scfg, buckets=[(2, 8)])
base.warmup()
rb = base.generate(prompts)

with obs.observe() as rec:
    srv = warmup(model, params, scfg, mesh=mesh, buckets=[(2, 8)])
    assert srv.warmup_report["2x8"]["plans"] > 0, srv.warmup_report
    rp = srv.generate(prompts)
    rep = srv.cache_report()

# decode matmuls routed through SchedulePlans: collectives were executed
ms = obs.collective_multiset(rec)
assert sum(ms.values()) > 0, "no collectives -- decode not plan-routed"
# warmup -> serve plan-cache pin: every serve-window lookup hit
assert rep["serve_window"]["hit_rate"] == 1.0, rep
assert rp.plan_probe["probed"] > 0 and rp.plan_probe["missing"] == 0, \
    rp.plan_probe
# plan-routed greedy tokens == unrouted baseline, bitwise
assert rb.sequences == rp.sequences, (rb.sequences, rp.sequences)

# the module-level generate(mesh=...) path agrees too
batch, lens = batch_requests(prompts, pad_to=8)
routed = generate(model, params, batch, scfg, mesh=mesh, lens=lens)
unrouted = generate(model, params, batch, scfg, lens=lens)
assert np.array_equal(routed, unrouted), (routed, unrouted)

# second batch stays pinned at 100% hits
srv.generate([[4, 4], [7, 7, 7]])
rep2 = srv.cache_report()
assert rep2["serve_window"]["hit_rate"] == 1.0, rep2
assert cache_info()["misses"] == rep2["info"]["misses"]
print("SERVE_PLAN_OK")
"""


@pytest.mark.timeout(600)
def test_plan_routed_serving_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _ROUTED_SCRIPT], capture_output=True,
        text=True, env=env, timeout=590)
    assert "SERVE_PLAN_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
