"""Overlapped collective-matmul execution (double-buffered lowering family).

Pins the PR-7 acceptance criteria:

  * ``overlap_capability`` / ``estimate(overlap=...)`` derive the
    overlapped flag from the lowering's capability, not the strategy name,
    and the cannon-vs-summa ranking flip that follows is pinned;
  * ``build_plan`` reifies the resolved variant on ``SchedulePlan.overlap``
    (== ``plan.cost.overlapped``), caches staged/overlapped twins
    separately, and rejects impossible requests;
  * an overlapped plan moves the identical collective multiset as its
    staged twin (trace level here; the executed interceptor/obs legs run
    in the forced-host subprocess test), and both variants pass
    ``conformance.check``;
  * per-axis ``axis:{name}`` α–β link classes price ``comm_by_axis`` terms
    (pooled fallback preserves the analytic identity);
  * prefetch collectives carry the ``comm="hidden"`` tag through obs;
  * the double-buffer rotation never reorders the movement homomorphism
    (hypothesis property over the Cannon family);
  * ``benchmarks/run.py --report`` renders bench-row lists with null
    ``us_per_call`` without crashing.
"""
import importlib.util
import json
import math
import os
import subprocess
import sys
from collections import Counter
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import obs
from repro.core.schedule import cannon_schedule
from repro.dist.api import estimate, overlap_capability
from repro.obs.profile import LinkParams, MachineProfile, default_profile
from repro.plan import build_plan, rank_mesh_strategies
from repro.plan.cache import plan_cache
from repro.plan.ir import TorusProgram
from repro.verify.conformance import check, memory_bound_words
from repro.verify.trace import trace_plan


def fake_mesh(sizes, names):
    total = math.prod(sizes)
    return SimpleNamespace(
        axis_names=tuple(names),
        shape=dict(zip(names, sizes)),
        size=total,
        devices=np.array([SimpleNamespace(id=i, platform="cpu")
                          for i in range(total)]),
    )


# --- capability / estimate derivation ----------------------------------------


def test_overlap_capability_by_lowering():
    assert overlap_capability("cannon")
    assert overlap_capability("summa")
    assert overlap_capability("cannon25d")
    assert overlap_capability("ring_ag") and overlap_capability("ring_rs")
    # pod25d: only the 3-axis (SUMMA-in-layer) program double-buffers
    assert overlap_capability("pod25d", grid=(2, 2, 2))
    assert overlap_capability("pod25d", grid=None)
    assert not overlap_capability("pod25d", grid=(4,))
    for s in ("xla_ag", "xla_rs", "local"):
        assert not overlap_capability(s)


def test_estimate_overlap_derived_not_name_keyed():
    # summa's decomposed-gather lowering makes it overlapped by default now
    e = estimate("summa", 4096, 4096, 4096, 16)
    assert e.overlapped
    assert e.total_s == max(e.compute_s, e.comm_s)
    staged = estimate("summa", 4096, 4096, 4096, 16, overlap=False)
    assert not staged.overlapped
    assert staged.total_s == staged.compute_s + staged.comm_s
    # identical word counts either way -- overlap is an execution property
    assert staged.comm_bytes == e.comm_bytes and staged.msgs == e.msgs
    # incapable lowerings cannot be priced overlapped
    with pytest.raises(ValueError, match="no overlapped lowering"):
        estimate("xla_ag", 1024, 1024, 1024, 8, overlap=True)
    with pytest.raises(ValueError, match="no overlapped lowering"):
        estimate("pod25d", 1024, 1024, 1024, 4, grid=(4,), overlap=True)
    assert not estimate("pod25d", 1024, 1024, 1024, 4, grid=(4,)).overlapped


def test_latency_profile_ranking_flip_capability_derived():
    """Regression pin for the old strategy-name overlap rule.  On a
    latency-dominated 4x4 machine, summa's 6 rounds beat cannon's 8 only
    because summa's chain lowering now prices as overlapped: max(3, 6) = 6
    < max(3, 8) = 8.  Under the old rule (summa staged) summa would pay
    3 + 6 = 9 > 8 and cannon would win -- the flip this test pins."""
    mesh = fake_mesh((4, 4), ("x", "y"))
    m = n = k = 4096
    prof = MachineProfile(
        platform="synth", peak_flops=2.86e9,  # compute ~= 3.0 s/device
        links=(("ici", LinkParams(1.0, 1e18)),))
    ranked = rank_mesh_strategies(m, n, k, mesh, profile=prof)
    assert ranked[0].strategy == "summa"
    by = {e.strategy: e for e in ranked}
    assert by["summa"].overlapped and by["cannon"].overlapped
    import dataclasses

    summa_staged = dataclasses.replace(by["summa"], overlapped=False)
    # the old rule's ordering: staged summa loses to overlapped cannon
    assert prof.seconds(summa_staged) > prof.seconds(by["cannon"])
    assert prof.seconds(by["summa"]) < prof.seconds(by["cannon"])


# --- build_plan resolution ----------------------------------------------------


def test_build_plan_reifies_overlap_capability():
    mesh = fake_mesh((2, 4), ("x", "y"))
    plan = build_plan(64, 64, 64, mesh=mesh, strategy="summa")
    assert plan.overlap            # strict max < sum win on the cost model
    assert plan.cost.overlapped == plan.overlap
    staged = build_plan(64, 64, 64, mesh=mesh, strategy="summa",
                        overlap=False)
    assert not staged.overlap and not staged.cost.overlapped
    assert plan_cache.info()["misses"] == 2  # twins cached separately
    again = build_plan(64, 64, 64, mesh=mesh, strategy="summa")
    assert again is plan and plan_cache.info()["hits"] == 1


def test_build_plan_default_cannon_overlapped_when_model_predicts_win():
    """Acceptance pin: ``max(compute, comm) < compute + comm`` holds for
    the default cannon cell (both terms positive), so the planner picks
    the double-buffered body."""
    mesh = fake_mesh((4, 4), ("x", "y"))
    plan = build_plan(256, 256, 256, mesh=mesh, strategy="cannon")
    assert plan.overlap
    e = plan.cost
    assert e.compute_s > 0 and e.comm_s > 0
    assert max(e.compute_s, e.comm_s) < e.compute_s + e.comm_s
    import dataclasses

    prof = default_profile()
    staged = dataclasses.replace(e, overlapped=False)
    over = dataclasses.replace(e, overlapped=True)
    assert prof.seconds(over) < prof.seconds(staged)


def test_build_plan_rejects_impossible_overlap_requests():
    with pytest.raises(ValueError, match="no overlapped lowering"):
        build_plan(64, 64, 64, mesh=None, overlap=True)
    mesh1d = fake_mesh((4,), ("t",))
    with pytest.raises(ValueError, match="intrinsically overlapped"):
        build_plan(64, 64, 64, mesh=mesh1d, strategy="ring_ag",
                   overlap=False)
    assert build_plan(64, 64, 64, mesh=mesh1d, strategy="ring_ag").overlap
    pod1d = fake_mesh((4,), ("pod",))
    with pytest.raises(ValueError, match="no overlapped lowering"):
        build_plan(64, 64, 64, mesh=pod1d, strategy="pod25d", axes=("pod",),
                   overlap=True)


# --- trace equivalence: overlapped twin moves the same words ------------------

TWIN_CELLS = (
    ("cannon", (3, 3), ("x", "y")),
    ("cannon", (4, 4), ("x", "y")),
    ("summa", (2, 4), ("x", "y")),
    ("summa", (4, 4), ("x", "y")),
    ("cannon25d", (2, 2, 2), ("pod", "x", "y")),
    ("pod25d", (2, 2, 2), ("pod", "x", "y")),
)


@pytest.mark.parametrize("strategy,sizes,names", TWIN_CELLS)
def test_overlapped_twin_same_movement_words_and_conformance(
        strategy, sizes, names):
    mesh = fake_mesh(sizes, names)
    staged = build_plan(24, 24, 24, mesh=mesh, strategy=strategy,
                        axes=names, overlap=False)
    over = build_plan(24, 24, 24, mesh=mesh, strategy=strategy,
                      axes=names, overlap=True)
    assert not staged.overlap and over.overlap
    ts, to = trace_plan(staged), trace_plan(over)
    # the movement homomorphism is an invariant of the variant choice
    assert ts.movement_words() == to.movement_words()
    if strategy in ("cannon", "cannon25d"):
        # torus double-buffering is a pure dataflow reorder: identical
        # records, not merely identical words
        assert Counter(r.key for r in ts.records) == \
            Counter(r.key for r in to.records)
    else:
        # decomposed gathers: all_gather records become one-hop ppermutes
        moved = [r for r in to.records if r.phase == "gather"]
        assert moved and all(r.kind == "ppermute" for r in moved)
    # both variants conform (structure + cost + memory bound)
    check(staged)
    check(over)
    assert to.peak_node_words <= memory_bound_words(over) + 1e-6


def test_overlapped_torus_peak_counts_double_buffers():
    mesh = fake_mesh((4, 4), ("x", "y"))
    staged = build_plan(32, 32, 32, mesh=mesh, strategy="cannon",
                       overlap=False)
    over = build_plan(32, 32, 32, mesh=mesh, strategy="cannon",
                      overlap=True)
    a_blk = b_blk = (32 // 4) * (32 // 4)
    assert trace_plan(over).peak_node_words == \
        trace_plan(staged).peak_node_words + a_blk + b_blk


# --- per-axis α–β pricing -----------------------------------------------------


def test_estimate_comm_by_axis_terms_sum_to_totals():
    mesh = fake_mesh((2, 4), ("x", "y"))
    ranked = rank_mesh_strategies(512, 512, 512, mesh)
    summa = next(e for e in ranked if e.strategy == "summa")
    assert {ax for ax, _, _ in summa.comm_by_axis} == {"x", "y"}
    assert sum(b for _, b, _ in summa.comm_by_axis) == \
        pytest.approx(summa.comm_bytes)
    assert sum(ms for _, _, ms in summa.comm_by_axis) == summa.msgs
    # without axis roles the estimate carries no terms
    assert estimate("summa", 512, 512, 512, 8).comm_by_axis == ()


def test_per_axis_profile_prices_each_axis():
    """m >> n: almost all bytes are A panels, which ride the y axis.  A
    profile with a slow axis:y must price the cell higher than one with a
    slow axis:x -- the pooled model cannot tell them apart."""
    mesh = fake_mesh((2, 4), ("x", "y"))
    ranked = rank_mesh_strategies(8192, 64, 1024, mesh)
    summa = next(e for e in ranked if e.strategy == "summa")
    a_bytes = dict((ax, b) for ax, b, _ in summa.comm_by_axis)
    assert a_bytes["y"] > a_bytes["x"]
    fast, slow = LinkParams(0.0, 1e12), LinkParams(0.0, 1e9)

    def prof(x_link, y_link):
        return MachineProfile(
            platform="synth", peak_flops=1e18,
            links=(("axis:x", x_link), ("axis:y", y_link),
                   ("ici", LinkParams(0.0, 1e12))))

    slow_y = prof(fast, slow).seconds(summa)
    slow_x = prof(slow, fast).seconds(summa)
    assert slow_y > slow_x
    # missing axis classes fall back to the pooled link: analytic identity
    pooled = MachineProfile(
        platform="synth", peak_flops=1e18,
        links=(("ici", LinkParams(0.0, 1e9)),))
    expected = max(2.0 * summa.m * summa.n * summa.k / summa.tp / 1e18,
                   summa.comm_bytes / 1e9)
    assert pooled.seconds(summa) == pytest.approx(expected)
    assert default_profile().seconds(summa) == pytest.approx(
        max(2.0 * summa.m * summa.n * summa.k / summa.tp
            / default_profile().peak_flops,
            summa.comm_bytes / default_profile().link("ici").bw_bytes_per_s))


# --- obs: hidden-comm tagging -------------------------------------------------


def test_collective_comm_tag_exposed_and_hidden():
    with obs.observe() as rec:
        with obs.span("plan.execute", strategy="cannon"):
            obs.record_collective("ppermute", 4, 16, perm=[(0, 1), (1, 0)])
            with obs.span("dist.prefetch", comm="hidden"):
                obs.record_collective("ppermute", 4, 16,
                                      perm=[(0, 1), (1, 0)])
    exposed, hidden = rec.collectives
    assert exposed.comm == "exposed" and hidden.comm == "hidden"
    assert exposed.key == hidden.key  # comm never enters the multiset key
    doc = obs.to_trace_events(rec)
    comms = [e["args"]["comm"] for e in doc["traceEvents"]
             if e["name"] == "collective.ppermute"]
    assert sorted(comms) == ["exposed", "hidden"]
    totals = obs.collective_totals(rec)
    assert totals["cannon"]["ppermute"]["count"] == 2
    assert totals["cannon"]["ppermute"]["shard_words"] == 32
    assert totals["cannon"]["ppermute"]["hidden_words"] == 16


# --- property: rotation preserves the movement homomorphism -------------------


def _apply(state, perm):
    if not perm:
        return state
    out = list(state)
    for src, dst in perm:
        out[dst] = state[src]
    return tuple(out)


def _compute_inputs(prog, overlapped):
    """Per-step (A-state, B-state) each local multiply consumes, simulating
    the staged and double-buffered bodies' dataflow on symbolic blocks."""
    n = prog.q * prog.q
    a = _apply(tuple(range(n)), prog.skew_a)
    b = _apply(tuple(range(n)), prog.skew_b)
    seen = []
    for step in range(prog.steps):
        if overlapped and step < prog.steps - 1:
            nxt_a = _apply(a, prog.step_a)
            nxt_b = _apply(b, prog.step_b)
        seen.append((a, b))
        if step < prog.steps - 1:
            if overlapped:
                a, b = nxt_a, nxt_b
            else:
                a = _apply(a, prog.step_a)
                b = _apply(b, prog.step_b)
    return seen


@settings(max_examples=30, deadline=None)
@given(q=st.integers(2, 7))
def test_double_buffer_rotation_preserves_movement(q):
    prog = TorusProgram.from_schedule(cannon_schedule(q))
    assert _compute_inputs(prog, False) == _compute_inputs(prog, True)


# --- executed conformance + bitwise identity (forced-host subprocess) ---------

_EXEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, numpy as np
from collections import Counter

from repro import obs
from repro.plan import build_plan
from repro.plan.lower_shard_map import _lower_shard_map
from repro.verify.conformance import check, compare_records
from repro.verify.trace import trace_plan

devs = np.array(jax.devices())
mesh44 = jax.make_mesh((4, 4), ("x", "y"), devices=devs[:16])
mesh24 = jax.make_mesh((2, 4), ("x", "y"), devices=devs[:8])
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)
b = jnp.asarray(rng.standard_normal((32, 40)), jnp.float32)

# overlapped cannon on the 4x4 mesh conforms with the SAME collective
# multiset as its staged twin, and the outputs are bitwise identical
plans = {}
outs = {}
for ov in (False, True):
    plan = build_plan(48, 40, 32, mesh=mesh44, strategy="cannon",
                      overlap=ov, use_cache=False)
    check(plan, measure=True)
    plans[ov] = plan
    outs[ov] = np.asarray(_lower_shard_map(plan)(a, b))
compare_records(trace_plan(plans[False]).records,
                trace_plan(plans[True]).records)
assert np.array_equal(outs[False], outs[True]), "cannon overlap not bitwise"

# summa's decomposed-gather twin: same movement words, allclose output
# (per-slab fp32 dots re-associate the contraction sum)
souts = {}
for ov in (False, True):
    plan = build_plan(48, 40, 32, mesh=mesh24, strategy="summa",
                      overlap=ov, use_cache=False)
    check(plan, measure=True)
    souts[ov] = np.asarray(_lower_shard_map(plan)(a, b))
    if ov:
        tr = trace_plan(plan)
        st = trace_plan(build_plan(48, 40, 32, mesh=mesh24,
                                   strategy="summa", overlap=False,
                                   use_cache=False))
        assert tr.movement_words() == st.movement_words()
assert np.allclose(souts[False], souts[True], rtol=1e-5, atol=1e-5)

# exposed-vs-hidden: the overlapped cannon body hides its step permutes
# behind the prefetch span; only the two skews stay exposed
plan = plans[True]
with obs.observe() as rec:
    with obs.span("plan.execute", strategy="cannon"):
        jax.block_until_ready(_lower_shard_map(plan)(a, b))
hidden = [ev for ev in rec.collectives if ev.comm == "hidden"]
exposed = [ev for ev in rec.collectives if ev.comm == "exposed"]
assert len(hidden) == 6, (len(hidden), len(exposed))   # 3 rounds x {A, B}
assert len(exposed) == 2, (len(hidden), len(exposed))  # the two skews
print("OVERLAP_EXEC_OK")
"""


@pytest.mark.timeout(600)
def test_overlapped_execution_conformance_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _EXEC_SCRIPT], capture_output=True,
        text=True, env=env, timeout=590,
    )
    assert "OVERLAP_EXEC_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
    )


# --- benchmarks/run.py --report regression ------------------------------------


def test_run_report_renders_null_us_rows(tmp_path, capsys):
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(_root(), "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rows = [
        {"schema": 2, "name": "lowerbound_gap", "us_per_call": None,
         "derived": "bound=1.0"},
        {"schema": 2, "name": "overlap_vs_staged_cannon_2x2",
         "us_per_call": 123.4, "derived": "speedup=1.10x"},
        {"schema": 2, "name": "bench_broken", "error": "boom"},
    ]
    p = tmp_path / "bench_results.json"
    p.write_text(json.dumps(rows))
    assert mod.run_report(str(p)) == 0
    out = capsys.readouterr().out
    assert "lowerbound_gap: -" in out
    assert "123.4 us" in out and "boom" in out
    # metrics snapshots still render
    snap = tmp_path / "metrics.json"
    snap.write_text(json.dumps({"schema": 1, "metrics": {}, "spans": {},
                                "collectives": {}}))
    assert mod.run_report(str(snap)) == 0


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
