"""Group machinery: laws, wreath products, Lemmas 3-5, fat-tree, hex."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fattree import FatTreeSchedule
from repro.core.groups import (CyclicGroup, HexLattice, Permutation,
                               ProductGroup, WreathTreeElement,
                               fat_tree_group_size, sigma_subgroup)
from repro.core.hexarray import HexSchedule
from repro.core.homomorphism import (AbelianHom, hom_exists_perm_to_cyclic,
                                     is_prime, lemma3_imprimitive_in_kernel,
                                     lemma5_q_divides_t)
from repro.core.zorder import (block_reuse_distance_traffic, morton_decode3,
                               morton_encode3, rowmajor_schedule,
                               zorder_schedule)

perms = st.integers(0, 5039).map(
    lambda n: _nth_permutation(n, 7)
)


def _nth_permutation(n, q):
    items = list(range(q))
    out = []
    import math
    for i in range(q, 0, -1):
        f = math.factorial(i - 1)
        idx, n = divmod(n, f)
        out.append(items.pop(idx % len(items)))
    return Permutation(tuple(out))


@settings(max_examples=50, deadline=None)
@given(a=perms, b=perms)
def test_permutation_group_laws(a, b):
    assert a.compose(a.inverse()).is_identity()
    assert a.compose(b).inverse().image == b.inverse().compose(a.inverse()).image
    assert a.order() >= 1
    assert a.power(a.order()).is_identity()


def test_sigma_subgroup_is_cyclic_transitive():
    q = 5
    sig = sigma_subgroup(q)
    assert len(sig) == q
    # transitive: orbit of 0 is everything
    assert {p(0) for p in sig} == set(range(q))


class TestLemmas:
    @pytest.mark.parametrize("q", [3, 5, 7])
    def test_lemma3(self, q):
        # imprimitive: product of disjoint transpositions / short cycles
        sigma = Permutation.from_cycles(q, [[0, 1]])
        assert not sigma.is_primitive()
        assert lemma3_imprimitive_in_kernel(sigma, q)

    def test_primitive_admits_nontrivial_hom(self):
        q = 5
        sigma = Permutation.cyclic_shift(q)
        assert sigma.is_primitive()
        assert hom_exists_perm_to_cyclic(sigma, q, 1)

    def test_lemma5(self):
        assert lemma5_q_divides_t(5, 10)
        assert not lemma5_q_divides_t(5, 12)

    def test_is_prime(self):
        assert [p for p in range(20) if is_prime(p)] == [2, 3, 5, 7, 11, 13, 17, 19]


@settings(max_examples=30, deadline=None)
@given(
    orders=st.tuples(st.sampled_from([2, 3, 4, 6]), st.sampled_from([2, 3, 4, 6])),
    data=st.data(),
)
def test_abelian_hom_well_defined(orders, data):
    target = ProductGroup((6, 6))
    images = tuple(
        data.draw(st.tuples(st.integers(0, 5), st.integers(0, 5)))
        for _ in orders
    )
    hom = AbelianHom(tuple(orders), target, images)
    if hom.is_well_defined():
        # spot-check rho(a+b) = rho(a)+rho(b) via exponent linearity
        e1 = data.draw(st.tuples(st.integers(0, 5), st.integers(0, 5)))
        e2 = data.draw(st.tuples(st.integers(0, 5), st.integers(0, 5)))
        lhs = hom.apply([a + b for a, b in zip(e1, e2)])
        rhs = target.add(hom.apply(e1), hom.apply(e2))
        assert lhs == rhs


class TestWreath:
    def test_identity(self):
        e = WreathTreeElement.identity(3)
        assert all(e.apply(i) == i for i in range(8))

    def test_level_swaps(self):
        root = WreathTreeElement.level_swap(3, 3, 0)
        assert root.apply(0) == 4 and root.apply(5) == 1
        leaf = WreathTreeElement.level_swap(3, 1, 0)
        assert leaf.apply(0) == 1 and leaf.apply(1) == 0 and leaf.apply(2) == 2

    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_compose_roundtrip(self, data):
        k = 3
        def rand_elem():
            sw = []
            for l in range(1, k + 1):
                sw.append(tuple(
                    data.draw(st.integers(0, 1)) for _ in range(2 ** (k - l))
                ))
            return WreathTreeElement(k, tuple(sw))
        a, b = rand_elem(), rand_elem()
        c = a.compose(b)
        for i in range(2 ** k):
            assert c.apply(i) == a.apply(b.apply(i))

    def test_group_size(self):
        assert fat_tree_group_size(2) == 8  # 2^(4-1)
        assert fat_tree_group_size(3) == 128


class TestFatTree:
    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_valid(self, d):
        assert FatTreeSchedule(d=d).validate()

    def test_paper_cost_claims(self):
        """Sec. 4.2: A moves n^2 across the top link; C never moves."""
        ft = FatTreeSchedule(d=2)
        assert ft.top_level_words() == ft.n ** 2
        # C stationary: position depends only on (k, i)
        for i in range(ft.n):
            for k in range(ft.n):
                assert ft.pos_C(k, i) == ft.pos_C(k, i)

    def test_base_case_matches_fig11(self):
        """d=1: 4 procs, 2 steps, 8 instructions; C_ki at proc (k,i)."""
        ft = FatTreeSchedule(d=1)
        cells = {ft.f(i, j, k) for i in range(2) for j in range(2) for k in range(2)}
        assert len(cells) == 8


class TestHex:
    def test_systolic_properties(self):
        props = HexSchedule(q=5).systolic_properties()
        assert all(props.values())

    def test_simulation_correct(self):
        hs = HexSchedule(q=6)
        A, B = np.random.rand(6, 6), np.random.rand(6, 6)
        np.testing.assert_allclose(hs.simulate(A, B), hs.reference(A, B), rtol=1e-10)

    def test_completion_time(self):
        assert HexSchedule(q=4).num_steps == 10  # 3q - 2


class TestZOrder:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 4095))
    def test_morton_roundtrip(self, code):
        i, j, k = morton_decode3(code)
        assert morton_encode3(i, j, k) == code

    @pytest.mark.parametrize("g", [(4, 4, 4), (3, 5, 2), (8, 1, 8)])
    def test_complete_traversal(self, g):
        order = zorder_schedule(*g)
        assert len(set(order)) == g[0] * g[1] * g[2]

    def test_zorder_beats_rowmajor(self):
        """Sec. 4.3: the space-bounded order's cache traffic beats the naive
        order whenever the cache is small relative to the working set (the
        cache-oblivious regime; when a whole operand fits, both are
        near-optimal and the claim is vacuous)."""
        g = 16  # operands are 256 blocks each
        z = zorder_schedule(g, g, g)
        r = rowmajor_schedule(g, g, g)
        for cache in (48, 192):
            assert (block_reuse_distance_traffic(z, cache)
                    < block_reuse_distance_traffic(r, cache))
