"""Executed-schedule conformance (acceptance criteria).

The subprocess job forces 16 host devices and asserts, for cannon, summa,
pod25d, cannon25d and both ring strategies on >= 3 mesh shapes each, that
the collectives the real shard_map lowering emits (captured at the
``repro.dist._collectives`` seam) form exactly the multiset the schedule
trace predicts, with word counts equal to the ``core.cost`` /
``dist.api.estimate`` analytics -- and that an injected wrong-permutation
mutation is caught, both statically and at the interceptor.

The ``conformance``-marked test runs the full strategy x mesh x
{square, ragged, batched} x dtype matrix in-process; tier-1 deselects it
(``addopts = -m "not conformance"``) and the dedicated CI job runs it at
``--xla_force_host_platform_device_count`` in {4, 8, 16}.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses
import jax, jax.numpy as jnp, numpy as np

from repro.plan import build_plan
from repro.verify import (ConformanceError, check, compare_records,
                          matrix_cells, measure_plan, run_matrix, trace_plan)

# --- measured triangle: every strategy on >= 3 mesh shapes ------------------
rows = run_matrix(cases=("square",), dtypes=(jnp.float32,))
bad = [r for r in rows if not r["ok"]]
assert not bad, f"non-conforming cells: {bad}"
per_strategy = {}
for r in rows:
    per_strategy.setdefault(r["strategy"], set()).add(r["mesh"])
for strat in ("cannon", "summa", "pod25d", "cannon25d", "ring_ag", "ring_rs"):
    assert len(per_strategy.get(strat, ())) >= 3, (strat, per_strategy)

# --- one ragged + one batched + one bf16 measured cell ----------------------
devs = np.array(jax.devices())
mesh22 = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
for kwargs in ({"m": 13, "n": 7, "k": 11},
               {"m": 5, "n": 8, "k": 12, "batch": (3,)},
               {"m": 16, "n": 16, "k": 16, "a_dtype": jnp.bfloat16,
                "b_dtype": jnp.bfloat16}):
    m, n, k = kwargs.pop("m"), kwargs.pop("n"), kwargs.pop("k")
    plan = build_plan(m, n, k, mesh=mesh22, strategy="cannon", **kwargs)
    check(plan, measure=True)

# --- hlo leg: compiled program's collective bytes visible to roofline -------
plan = build_plan(24, 24, 24, mesh=mesh22, strategy="cannon")
rep = check(plan, measure=True, hlo=True)
assert rep.hlo_collective_bytes and rep.hlo_collective_bytes > 0

# --- injected wrong-permutation mutations -----------------------------------
prog = plan.torus
pairs = list(prog.step_a)
pairs[0], pairs[1] = (pairs[0][0], pairs[1][1]), (pairs[1][0], pairs[0][1])
bad_plan = dataclasses.replace(
    plan, torus=dataclasses.replace(prog, step_a=tuple(pairs)))
try:
    check(bad_plan)
    raise SystemExit("static mutation not caught")
except ConformanceError:
    pass
# executed-program mutation: run the mutated lowering, compare against the
# unmutated plan's trace -- the interceptor multiset must diverge
cap = measure_plan(bad_plan)
try:
    compare_records(trace_plan(plan).records, cap.records)
    raise SystemExit("executed mutation not caught by interceptor")
except ConformanceError:
    pass

print("CONFORMANCE_OK")
"""


@pytest.mark.timeout(600)
def test_conformance_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=590,
    )
    assert "CONFORMANCE_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
    )


@pytest.mark.conformance
@pytest.mark.timeout(1800)
def test_conformance_matrix_full():
    """Full matrix at whatever forced-host device count the job set; the CI
    conformance job runs this at 4, 8, and 16 devices."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    from repro.verify import run_matrix

    rows = run_matrix()
    bad = [r for r in rows if not r["ok"]]
    assert not bad, f"{len(bad)}/{len(rows)} non-conforming cells: {bad[:5]}"
    assert rows, "empty conformance matrix"


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
