"""Plan IR, planner, cache, and cost-model-ranked mesh dispatch.

Pure-planning tests: no multi-device execution (that is
tests/test_plan_exec.py's subprocess job), so meshes here are duck-typed
stand-ins carrying exactly the attributes the planner reads
(axis_names / shape / size / devices).
"""
import math
from types import SimpleNamespace

import numpy as np
import pytest

import jax.numpy as jnp

from repro import plan as planlib
from repro.core.schedule import cannon_schedule
from repro.core.zorder import enclosing_pow2, zorder_schedule
from repro.dist.api import _mesh_heuristic, choose
from repro.plan import (SchedulePlan, TilingPlan, TorusProgram, build_plan,
                        cache_clear, cache_stats, lower_tiling,
                        mesh_candidates, mesh_fingerprint)
from repro.runtime.sharding import planned_matmul_axes


def fake_mesh(sizes, names):
    """Planner-facing mesh stand-in (no devices backing it)."""
    total = math.prod(sizes)
    return SimpleNamespace(
        axis_names=tuple(names),
        shape=dict(zip(names, sizes)),
        size=total,
        devices=np.array([SimpleNamespace(id=i, platform="cpu")
                          for i in range(total)]),
    )


# ---------------------------------------------------------------------------
# choose(mesh=...) must rank with the cost model, topology only as filter
# ---------------------------------------------------------------------------


def test_choose_mesh_overrules_topology_heuristic():
    """Regression pin for the PR-1 bug: the mesh path of choose() returned
    a strategy from topology shape alone.  On a square mesh with a huge
    contraction dim the heuristic says Cannon (square => Cannon), but
    Cannon shifts O(k)-sized panels while reduce-scattering the small
    output is orders cheaper -- the cost model must win."""
    mesh = fake_mesh((2, 2), ("x", "y"))
    m, n, k = 256, 256, 1 << 16
    assert _mesh_heuristic(mesh, m, n, k) == "cannon"
    assert choose(m, n, k, mesh=mesh) == "ring_rs"


def test_choose_mesh_agrees_when_topology_is_right():
    # compute-bound square problem: Cannon's overlapped one-hop shifts win
    mesh = fake_mesh((2, 2), ("x", "y"))
    assert choose(4096, 4096, 4096, mesh=mesh) == \
        _mesh_heuristic(mesh, 4096, 4096, 4096) == "cannon"
    # 1-D ring: gather the smaller operand, as the heuristic also says
    ring = fake_mesh((4,), ("t",))
    assert choose(64, 1024, 64, mesh=ring) == "ring_ag"
    assert choose(64, 64, 1024, mesh=ring) == "ring_rs"


def test_mesh_candidates_topology_filter():
    assert mesh_candidates(fake_mesh((1,), ("t",))) == ("local",)
    c2 = mesh_candidates(fake_mesh((2, 2), ("x", "y")))
    assert "cannon" in c2 and "summa" in c2 and "ring_ag" in c2
    # rectangular 2-D mesh: Cannon filtered out, SUMMA stays
    c_rect = mesh_candidates(fake_mesh((2, 4), ("x", "y")))
    assert "cannon" not in c_rect and "summa" in c_rect
    c3 = mesh_candidates(fake_mesh((2, 2, 2), ("pod", "x", "y")))
    assert "cannon25d" in c3 and "pod25d" in c3
    c3r = mesh_candidates(fake_mesh((2, 2, 4), ("pod", "x", "y")))
    assert "cannon25d" not in c3r and "pod25d" in c3r


# ---------------------------------------------------------------------------
# plan IR reifies the schedule algebra
# ---------------------------------------------------------------------------


def test_cannon_plan_reifies_schedule_perms():
    mesh = fake_mesh((3, 3), ("x", "y"))
    plan = build_plan(30, 30, 30, mesh=mesh, strategy="cannon",
                      a_dtype=jnp.float32, b_dtype=jnp.float32)
    assert isinstance(plan, SchedulePlan)
    sched = cannon_schedule(3)
    assert plan.schedule == sched
    prog = plan.torus
    assert isinstance(prog, TorusProgram)
    assert prog.q == 3 and prog.steps == 3
    assert dict(prog.shifts) == sched.movements()
    assert prog.skew_a == tuple(sched.placement_perm("A"))
    assert prog.step_b == tuple(sched.movement_perm("B"))
    # Cannon's C is stationary in canonical layout: collection elided
    assert prog.collect_c == ()
    assert plan.pad_a == (3, 3) and plan.grid == (3, 3)
    assert plan.replication == 1
    assert plan.cost is not None and plan.cost.strategy == "cannon"


def test_25d_plan_replication_and_padding():
    mesh = fake_mesh((2, 2, 2), ("pod", "x", "y"))
    plan = build_plan(64, 64, 64, mesh=mesh, strategy="cannon25d")
    assert plan.replication == 2
    assert plan.pad_a == (2, 4) and plan.pad_b == (4, 2)
    plan_s = build_plan(64, 64, 64, mesh=mesh, strategy="pod25d")
    assert plan_s.pad_a == (2, 8) and plan_s.pad_b == (8, 2)


def test_ring_plan_flattens_all_axes():
    mesh = fake_mesh((2, 2), ("x", "y"))
    plan = build_plan(64, 64, 64, mesh=mesh, strategy="ring_ag")
    assert plan.axes == ("x", "y") and plan.grid == (4,)
    assert plan.pad_a == (4, 1) and plan.pad_b == (1, 4)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hits_and_key_sensitivity():
    cache_clear()
    mesh = fake_mesh((2, 2), ("x", "y"))
    p1 = build_plan(128, 128, 128, mesh=mesh, strategy="cannon")
    s = cache_stats()
    assert s["misses"] == 1 and s["hits"] == 0
    p2 = build_plan(128, 128, 128, mesh=mesh, strategy="cannon")
    s = cache_stats()
    assert s["hits"] == 1 and p2 is p1
    # every key component must invalidate: shape, dtype, mesh, strategy
    build_plan(128, 128, 256, mesh=mesh, strategy="cannon")
    build_plan(128, 128, 128, mesh=mesh, strategy="cannon",
               a_dtype=jnp.bfloat16)
    build_plan(128, 128, 128, mesh=mesh, strategy="cannon",
               out_dtype=jnp.bfloat16)
    build_plan(128, 128, 128, mesh=mesh, strategy="summa")
    build_plan(128, 128, 128, mesh=fake_mesh((2, 2), ("a", "b")),
               strategy="cannon")
    build_plan(128, 128, 128, mesh=mesh, strategy="cannon", batch=(8,))
    s = cache_stats()
    assert s["hits"] == 1 and s["misses"] == 7


def test_mesh_fingerprint_distinguishes_meshes():
    m1 = fake_mesh((2, 2), ("x", "y"))
    m2 = fake_mesh((4,), ("x",))
    assert mesh_fingerprint(m1) != mesh_fingerprint(m2)
    assert mesh_fingerprint(None) is None
    assert mesh_fingerprint(m1) == mesh_fingerprint(fake_mesh((2, 2), ("x", "y")))


def test_mesh_fingerprint_memo_releases_dead_meshes():
    """Regression pin for the lru_cache leak: the fingerprint memo must not
    keep a mesh (and its device handles) alive after the caller drops it --
    elastic re-meshing churns through meshes for the process lifetime.
    SimpleNamespace is unhashable (it takes the uncached path), so this
    uses a plain-class stand-in that is hashable AND weakrefable, like a
    real jax mesh."""
    import gc
    import weakref

    class HashableMesh:
        def __init__(self, proto):
            self.axis_names = proto.axis_names
            self.shape = proto.shape
            self.size = proto.size
            self.devices = proto.devices

    mesh = HashableMesh(fake_mesh((2, 2), ("x", "y")))
    fp = mesh_fingerprint(mesh)
    assert mesh_fingerprint(mesh) is fp  # memoized per mesh object
    ref = weakref.ref(mesh)
    del mesh
    gc.collect()
    assert ref() is None, "fingerprint memo pinned a dead mesh"


# ---------------------------------------------------------------------------
# local execution paths (1 device, no mesh)
# ---------------------------------------------------------------------------


def test_symmetric_matmul_batched_local():
    import jax
    from repro.dist.api import symmetric_matmul

    a = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 7), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (7, 4), jnp.float32)
    out = symmetric_matmul(a, b)
    assert out.shape == (3, 5, 4)
    np.testing.assert_allclose(
        np.asarray(out), np.einsum("bmk,kn->bmn", a, b), rtol=1e-5, atol=1e-5)
    # batched-both
    b3 = jax.random.normal(jax.random.PRNGKey(2), (3, 7, 4), jnp.float32)
    out2 = symmetric_matmul(a, b3)
    np.testing.assert_allclose(
        np.asarray(out2), np.einsum("bmk,bkn->bmn", a, b3), rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        symmetric_matmul(a, jnp.zeros((2, 7, 4)))  # mismatched batch dims
    with pytest.raises(ValueError):
        symmetric_matmul(a, jnp.zeros((8, 4)))  # contraction mismatch


def test_lower_tiling_default_is_local_matmul():
    from repro.dist.local import local_matmul

    assert lower_tiling(TilingPlan()) is local_matmul
    assert not TilingPlan(order="rowmajor").is_default


def test_lower_tiling_override_matches_oracle():
    import jax

    a = jax.random.normal(jax.random.PRNGKey(0), (48, 32), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    fn = lower_tiling(TilingPlan(order="rowmajor", block_m=16))
    np.testing.assert_allclose(
        np.asarray(fn(a, b, out_dtype=jnp.float32)), np.asarray(a @ b),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sharding consumers consult plan.estimate
# ---------------------------------------------------------------------------


def test_planned_matmul_axes_recovers_megatron_convention():
    mesh = fake_mesh((4,), ("model",))
    # up-projection d_in < d_out: gather the small activations (column-par)
    assert planned_matmul_axes(1024, 4096, mesh=mesh) == (None, "model")
    # down-projection d_in > d_out: reduce-scatter the small output (row-par)
    assert planned_matmul_axes(4096, 1024, mesh=mesh) == ("model", None)
    # no model axis: replicated
    assert planned_matmul_axes(1024, 4096, mesh=fake_mesh((4,), ("data",))) \
        == (None, None)


def test_ranked_linear_spec_guards():
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding_rules import ranked_linear_spec

    mesh = fake_mesh((4,), ("model",))
    assert ranked_linear_spec((1024, 4096), mesh) == P(None, "model")
    assert ranked_linear_spec((4096, 1024), mesh) == P("model", None)
    # too small / wrong rank / non-divisible -> replicated
    assert ranked_linear_spec((64, 4096), mesh) == P()
    assert ranked_linear_spec((4096,), mesh) == P()
    # chosen (row-parallel) axis not divisible by model=4 -> dropped
    assert ranked_linear_spec((4098, 130), mesh) == P(None, None)


# ---------------------------------------------------------------------------
# zorder enclosing-cube simplification (satellite)
# ---------------------------------------------------------------------------


def _legacy_side(gi, gj, gk):
    """The pre-simplification bit_length + corrective-while form."""
    side = 1 << max(gi - 1, gj - 1, gk - 1, 0).bit_length() \
        if max(gi, gj, gk) > 1 else 1
    while side < max(gi, gj, gk):
        side <<= 1
    return side


def test_enclosing_pow2_matches_legacy_form():
    for n in list(range(1, 600)) + [1023, 1024, 1025, 4095, 4096, 4097]:
        s = enclosing_pow2(n)
        assert s == _legacy_side(n, 1, 1)
        assert s >= n and s & (s - 1) == 0  # power of two, covers n
        assert s == 1 or s < 2 * n  # minimal


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=80, deadline=None)
    @given(g=st.tuples(st.integers(1, 21), st.integers(1, 21),
                       st.integers(1, 21)))
    def test_zorder_non_pow2_grids_property(g):
        """Non-power-of-two grids: the filtered enclosing-cube traversal is
        a permutation of the grid and its side is the minimal pow2 cover."""
        order = zorder_schedule(*g)
        assert len(order) == g[0] * g[1] * g[2]
        assert len(set(order)) == len(order)
        side = enclosing_pow2(max(g))
        assert all(i < side and j < side and k < side for i, j, k in order)
except ImportError:  # pragma: no cover - hypothesis stub covers CI
    pass
