"""Per-architecture smoke tests + recurrence/attention consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.registry import build_model

B, S = 2, 64


def _batch(cfg, key=0):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(key + 1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "audio":
        batch["src_embed"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, S, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward + loss on CPU; shapes + finiteness."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)
    assert 0.0 < float(loss) < 20.0
    logits, _ = model.forward(params, batch["tokens"]) if cfg.family != "audio" \
        else model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["llama3_2_1b", "minicpm3_4b", "h2o_danube3_4b",
                                  "xlstm_350m", "zamba2_2_7b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode logits must match the parallel forward pass --
    the strongest cache-correctness check (covers GQA full cache, SWA
    rolling cache, MLA absorbed decode, mLSTM/sLSTM and SSD states)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 24
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0, cfg.vocab_size)
    fwd_logits, _ = model.forward(params, tokens)

    cache = model.init_cache(B, T)
    step_fn = jax.jit(model.decode_step)
    errs = []
    for t in range(T):
        logits, cache = step_fn(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        ref = fwd_logits[:, t]
        errs.append(float(jnp.max(jnp.abs(logits - ref))))
    scale = float(jnp.max(jnp.abs(fwd_logits))) + 1e-6
    assert max(errs) / scale < 0.08, f"max rel err {max(errs)/scale}"


def test_moe_load_balance_loss_positive():
    cfg = get_smoke_config("qwen3_moe_30b_a3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, aux = model.forward(params, _batch(cfg)["tokens"])
    assert float(aux) > 0.5  # ~1.0 for balanced routing


def test_param_count_formula_matches_init():
    for arch in ("llama3_2_1b", "qwen3_moe_30b_a3b", "zamba2_2_7b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
        expect = cfg.param_count()
        assert abs(actual - expect) / actual < 0.05, (arch, actual, expect)


def test_full_configs_match_assignment():
    """Exact published dims from the assignment table."""
    c = get_config("llama3.2-1b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (16, 2048, 32, 8, 8192, 128256)
    c = get_config("granite-20b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (52, 6144, 48, 1)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.num_experts, c.top_k, c.vocab_size) == (128, 8, 151936)
    c = get_config("deepseek-moe-16b")
    assert (c.num_experts, c.num_shared_experts, c.top_k) == (64, 2, 6)
    c = get_config("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = get_config("minicpm3-4b")
    assert (c.q_lora_rank, c.kv_lora_rank) == (768, 256)
    c = get_config("seamless-m4t-medium")
    assert (c.enc_layers, c.dec_layers, c.vocab_size) == (12, 12, 256206)
    c = get_config("h2o-danube-3-4b")
    assert (c.num_layers, c.d_model, c.window) == (24, 3840, 4096)
    c = get_config("chameleon-34b")
    assert (c.num_layers, c.d_model, c.vocab_size) == (48, 8192, 65536)
    c = get_config("xlstm-350m")
    assert (c.num_layers, c.d_model, c.d_ff) == (24, 1024, 0)


class TestRecurrentCores:
    def test_ssd_chunked_vs_recurrent(self):
        from repro.layers.mamba2 import _ssd_chunk_scan
        B_, S_, H_, P_, N_ = 2, 32, 3, 4, 5
        xh = jax.random.normal(jax.random.PRNGKey(0), (B_, S_, H_, P_))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B_, S_, H_)))
        Bm = jax.random.normal(jax.random.PRNGKey(2), (B_, S_, N_))
        Cm = jax.random.normal(jax.random.PRNGKey(3), (B_, S_, N_))
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (H_,)))
        y8, _ = _ssd_chunk_scan(xh, dt, Bm, Cm, A, chunk=8)
        y16, _ = _ssd_chunk_scan(xh, dt, Bm, Cm, A, chunk=16)
        assert float(jnp.max(jnp.abs(y8 - y16))) < 1e-4  # chunk-invariance

        h = jnp.zeros((B_, H_, P_, N_))
        ys = []
        for t in range(S_):
            a = jnp.exp(dt[:, t] * A)
            h = h * a[:, :, None, None] + jnp.einsum(
                "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
            ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
        ref = jnp.stack(ys, axis=1)
        assert float(jnp.max(jnp.abs(y8 - ref))) < 1e-4

    def test_mlstm_chunked_vs_recurrent(self):
        from repro.layers.xlstm import _mlstm_chunk_scan
        B_, S_, H_, D_ = 2, 32, 2, 4
        q = jax.random.normal(jax.random.PRNGKey(5), (B_, S_, H_, D_))
        k = jax.random.normal(jax.random.PRNGKey(6), (B_, S_, H_, D_))
        v = jax.random.normal(jax.random.PRNGKey(7), (B_, S_, H_, D_))
        li = jax.nn.log_sigmoid(jax.random.normal(jax.random.PRNGKey(8), (B_, S_, H_)))
        lf = jax.nn.log_sigmoid(jax.random.normal(jax.random.PRNGKey(9), (B_, S_, H_)) + 2)
        y, _ = _mlstm_chunk_scan(q, k, v, li, lf, chunk=8)
        scale = D_ ** -0.5
        C = jnp.zeros((B_, H_, D_, D_)); n = jnp.zeros((B_, H_, D_))
        ys = []
        for t in range(S_):
            f = jnp.exp(lf[:, t]); i = jnp.exp(li[:, t])
            C = C * f[:, :, None, None] + jnp.einsum("bhd,bhe,bh->bhde", k[:, t], v[:, t], i)
            n = n * f[:, :, None] + k[:, t] * i[:, :, None]
            yt = jnp.einsum("bhd,bhde->bhe", q[:, t], C) * scale
            qn = jnp.einsum("bhd,bhd->bh", q[:, t], n) * scale
            ys.append(yt / jnp.maximum(jnp.abs(qn), 1.0)[..., None])
        ref = jnp.stack(ys, axis=1)
        assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


@pytest.mark.parametrize("arch", ["llama3_2_1b", "minicpm3_4b"])
def test_prefill_matches_stepwise_decode(arch):
    """One-pass prefill must fill the cache identically to step-by-step
    decode (and return the same last-token logits)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab_size)

    cache_a = model.init_cache(B, 32)
    logits_a, cache_a = jax.jit(model.prefill)(params, cache_a, tokens)

    cache_b = model.init_cache(B, 32)
    step = jax.jit(model.decode_step)
    for t in range(T):
        logits_b, cache_b = step(params, cache_b, tokens[:, t : t + 1], jnp.int32(t))

    scale = float(jnp.max(jnp.abs(logits_b))) + 1e-6
    assert float(jnp.max(jnp.abs(logits_a - logits_b))) / scale < 0.05
    err = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        cache_a, cache_b,
    )
    assert max(jax.tree.leaves(err)) < 0.05, err

    # continuing decode from the prefilled cache matches too
    nxt = jnp.zeros((B, 1), jnp.int32)
    la, _ = step(params, cache_a, nxt, jnp.int32(T))
    lb, _ = step(params, cache_b, nxt, jnp.int32(T))
    assert float(jnp.max(jnp.abs(la - lb))) / scale < 0.05
