"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import attention_ref, mha
from repro.kernels.matmul import matmul, matmul_ref, zorder_matmul
from repro.kernels.matmul.kernel import default_blocks, vmem_working_set_bytes


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-4


class TestZOrderMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [
        (128, 128, 128), (256, 384, 512), (200, 300, 260), (512, 128, 384),
    ])
    def test_against_oracle(self, shape, dtype):
        m, k, n = shape
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
        out = matmul(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
        ref = matmul_ref(a, b)
        err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
        scale = jnp.max(jnp.abs(ref.astype(jnp.float32))) + 1e-6
        assert float(err / scale) < _tol(dtype)

    @pytest.mark.parametrize("order", ["zorder", "rowmajor"])
    def test_orders_agree(self, order):
        a = jax.random.normal(jax.random.PRNGKey(2), (256, 256), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(3), (256, 256), jnp.float32)
        out = zorder_matmul(a, b, block_m=128, block_n=128, block_k=128,
                            order=order, interpret=True)
        assert jnp.allclose(out, matmul_ref(a, b), atol=1e-3)

    def test_default_blocks_fit_vmem(self):
        for dims in [(4096, 4096, 4096), (128, 32768, 256), (8192, 512, 8192)]:
            bm, bn, bk = default_blocks(*dims)
            assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
            assert vmem_working_set_bytes(bm, bn, bk) < 128 * 1024 * 1024

    @settings(max_examples=60, deadline=None)
    @given(dims=st.tuples(st.sampled_from([128, 512, 4096, 32768]),
                          st.sampled_from([128, 512, 4096, 32768]),
                          st.sampled_from([128, 512, 4096, 32768])),
           dtype_bytes=st.sampled_from([1, 2, 4]),
           out_dtype_bytes=st.sampled_from([2, 4]))
    def test_default_blocks_fit_vmem_any_dtype(self, dims, dtype_bytes,
                                               out_dtype_bytes):
        """The heuristic must fit the VMEM budget at the ACTUAL operand and
        output byte widths, not the bf16 defaults -- fp32 operands halve
        the feasible block space."""
        bm, bn, bk = default_blocks(*dims, dtype_bytes, out_dtype_bytes)
        assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
        assert vmem_working_set_bytes(
            bm, bn, bk, dtype_bytes, out_dtype_bytes) < 128 * 1024 * 1024

    def test_tiny_fallback(self):
        a = jax.random.normal(jax.random.PRNGKey(4), (8, 16), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(5), (16, 8), jnp.float32)
        assert jnp.allclose(matmul(a, b), a @ b, atol=1e-5)


class TestFlashAttention:
    def _ref(self, q, k, v, **kw):
        B, S, H, D = q.shape
        qh = q.transpose(0, 2, 1, 3).reshape(-1, S, D)
        kh = k.transpose(0, 2, 1, 3).reshape(-1, k.shape[1], D)
        vh = v.transpose(0, 2, 1, 3).reshape(-1, v.shape[1], D)
        o = attention_ref(qh, kh, vh, **kw)
        return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    def test_causal_gqa(self, hq, hkv, dtype):
        B, S, D = 2, 256, 32
        q = jax.random.normal(jax.random.PRNGKey(0), (B, S, hq, D), dtype)
        k = jax.random.normal(jax.random.PRNGKey(1), (B, S, hkv, D), dtype)
        v = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D), dtype)
        out = mha(q, k, v, causal=True, block_q=128, block_kv=128, interpret=True)
        ref = self._ref(q, k, v, causal=True)
        err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
        assert float(err) < (0.05 if dtype == jnp.bfloat16 else 1e-4)

    @pytest.mark.parametrize("window", [64, 200])
    def test_sliding_window(self, window):
        B, S, H, D = 1, 384, 2, 32
        q = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(4), (B, S, H, D), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, D), jnp.float32)
        out = mha(q, k, v, causal=True, window=window,
                  block_q=128, block_kv=128, interpret=True)
        ref = self._ref(q, k, v, causal=True, window=window)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-4

    def test_unaligned_query_length(self):
        B, S, H, D = 1, 300, 2, 32
        q = jax.random.normal(jax.random.PRNGKey(6), (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(7), (B, 512, H, D), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(8), (B, 512, H, D), jnp.float32)
        out = mha(q, k, v, causal=True, block_q=128, block_kv=128, interpret=True)
        ref = self._ref(q, k, v, causal=True)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
