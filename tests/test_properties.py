"""Hypothesis property tests on system-level invariants."""
import math

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cost import (bandwidth_lower_bound,
                             memory_independent_lower_bound,
                             schedule_25d_cost, torus_schedule_cost)
from repro.core.schedule import (Torus25DSchedule, TorusSchedule,
                                 cannon_schedule, torus_hops)
from repro.core.zorder import zorder_schedule
from repro.dist.api import estimate
from repro.layers.embed import padded_vocab


@settings(max_examples=60, deadline=None)
@given(q=st.sampled_from([4, 6, 8, 12]), c=st.sampled_from([1, 2, 4]))
def test_25d_partition_property(q, c):
    """Every instruction lands in exactly one (x, y, z, step) cell and each
    layer's contraction slab covers [q] exactly once."""
    if q % c:
        return
    s = Torus25DSchedule(q=q, c=c)
    seen = set()
    for i in range(q):
        for j in range(q):
            for k in range(q):
                cell = s.f(i, j, k)
                assert cell not in seen
                seen.add(cell)
                x, y, z, step = cell
                lo, hi = s.layer_contraction_slab(z)
                assert lo <= j < hi
    assert len(seen) == q ** 3


@settings(max_examples=60, deadline=None)
@given(
    q=st.sampled_from([3, 5, 7]),
    vec=st.tuples(st.integers(-20, 20), st.integers(-20, 20)),
)
def test_torus_hops_metric(q, vec):
    """torus_hops is a metric compatible with the group: symmetric under
    negation, zero only at identity, bounded by q."""
    h = torus_hops(vec, q)
    hn = torus_hops((-vec[0], -vec[1]), q)
    assert h == hn
    assert 0 <= h <= q
    assert (h == 0) == (vec[0] % q == 0 and vec[1] % q == 0)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(256, 65536), n=st.integers(256, 16384),
    k=st.integers(256, 16384), tp=st.sampled_from([4, 8, 16]),
)
def test_cost_model_invariants(m, n, k, tp):
    """Ring variants never cost more than their unoverlapped counterparts;
    costs are positive and monotone in the matmul volume."""
    for pair in (("xla_ag", "ring_ag"), ("xla_rs", "ring_rs")):
        plain = estimate(pair[0], m, n, k, tp)
        ring = estimate(pair[1], m, n, k, tp)
        assert ring.total_s <= plain.total_s + 1e-12
        assert plain.compute_s > 0 and plain.comm_s >= 0
    small = estimate("xla_ag", m, n, k, tp).total_s
    big = estimate("xla_ag", 2 * m, n, k, tp).total_s
    assert big >= small


@settings(max_examples=40, deadline=None)
@given(g=st.tuples(st.integers(1, 9), st.integers(1, 9), st.integers(1, 9)))
def test_zorder_is_permutation(g):
    order = zorder_schedule(*g)
    assert len(order) == g[0] * g[1] * g[2]
    assert len(set(order)) == len(order)
    assert all(0 <= i < g[0] and 0 <= j < g[1] and 0 <= k < g[2]
               for i, j, k in order)


@settings(max_examples=80, deadline=None)
@given(v=st.integers(1, 1_000_000))
def test_padded_vocab_properties(v):
    p = padded_vocab(v)
    assert p >= v and p % 256 == 0 and p - v < 256


@settings(max_examples=60, deadline=None)
@given(
    q=st.sampled_from([2, 3, 4, 6, 8, 12, 16]),
    mult=st.integers(1, 64),
)
def test_torus_cost_never_beats_lower_bounds(q, mult):
    """The paper's schedules are feasible, so their analytic word counts
    must sit at or above the Irony-Toledo-Tiskin bandwidth bound (at the
    schedule's own 3-blocks-per-node memory) and the memory-independent
    bound, for every (n, q)."""
    n = q * mult
    rep = torus_schedule_cost(cannon_schedule(q), n)
    p = q * q
    M = 3.0 * (n / q) ** 2
    assert rep.words_per_node >= bandwidth_lower_bound(n, p, M) - 1e-9
    assert rep.words_per_node >= memory_independent_lower_bound(n, p) - 1e-9


@settings(max_examples=60, deadline=None)
@given(
    q=st.sampled_from([2, 4, 6, 8, 12, 16]),
    c=st.sampled_from([1, 2, 3, 4]),
    mult=st.integers(1, 32),
)
def test_25d_cost_never_beats_lower_bounds(q, c, mult):
    """Replication (the Sec.-2.5 memory-for-communication trade) lowers the
    words but raises M -- the ITT bound moves with it and is never beaten,
    nor is the memory-independent floor, across random (n, q, c)."""
    if q % c:
        return
    n = q * mult
    sched = Torus25DSchedule(q=q, c=c)
    rep = schedule_25d_cost(sched, n)
    p = q * q * c
    M = 3.0 * c * (n / q) ** 2  # c-fold replicated blocks per node
    assert rep.words_per_node >= bandwidth_lower_bound(n, p, M) - 1e-9
    assert rep.words_per_node >= memory_independent_lower_bound(n, p) - 1e-9


@settings(max_examples=30, deadline=None)
@given(
    q=st.sampled_from([3, 5]),
    rows=st.tuples(*[st.tuples(*[st.integers(-1, 1)] * 3)] * 3),
)
def test_valid_schedules_have_consistent_movement(q, rows):
    """For any embedding schedule whose diagrams are solvable, re-deriving
    the absent-index constraint holds: (x_a, y_a) == t_a * mu (mod q)."""
    sched = TorusSchedule(q=q, t=q, M=tuple(tuple(v % q for v in r) for r in rows))
    if not sched.is_embedding():
        return
    moves = sched.movements()
    if moves is None:
        return
    from repro.core.schedule import VAR_INDEX
    for var, mv in moves.items():
        _, absent = VAR_INDEX[var]
        xa, ya, ta = sched.M[absent]
        assert (ta * mv[0] - xa) % q == 0
        assert (ta * mv[1] - ya) % q == 0
