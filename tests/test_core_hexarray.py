"""Direct unit tests for core.hexarray (Sec. D.2 systolic schedule).

Pin the schedule's validity (one MAC per node per step), hop counts
(every stream moves exactly one lattice link per step, in its fixed
direction), and boundary sizes (the active region is the hexagon of side
q; the wavefront spans 3q - 2 steps).
"""
import numpy as np
import pytest

from repro.core.groups import HexLattice
from repro.core.hexarray import HexSchedule
from repro.verify import trace_hex
from repro.verify.trace import hex_element_positions


@pytest.mark.parametrize("q", [1, 2, 3, 5])
class TestValidity:
    def test_one_mac_per_node_per_step(self, q):
        hs = HexSchedule(q=q)
        cells = {}
        for i in range(q):
            for j in range(q):
                for k in range(q):
                    key = hs.f(i, j, k)
                    assert key not in cells, "two MACs on one node/step"
                    cells[key] = (i, j, k)
        assert len(cells) == q ** 3

    def test_boundary_sizes(self, q):
        """Active nodes form the hexagon of side q: 3q^2 - 3q + 1 cells;
        completion takes 3q - 2 steps."""
        hs = HexSchedule(q=q)
        nodes = {hs.f(i, j, k)[0]
                 for i in range(q) for j in range(q) for k in range(q)}
        assert len(nodes) == 3 * q * q - 3 * q + 1
        assert hs.num_steps == 3 * q - 2
        times = {hs.f(i, j, k)[1]
                 for i in range(q) for j in range(q) for k in range(q)}
        assert times == set(range(3 * q - 2))


@pytest.mark.parametrize("q", [2, 3, 4])
class TestHopCounts:
    def test_movement_vectors_are_single_links(self, q):
        hs = HexSchedule(q=q)
        lat = HexLattice()
        mv = hs.movement_vectors()
        assert set(mv) == {"A", "B", "C"}
        for vec in mv.values():
            assert lat.link_hops(vec) == 1

    def test_streams_move_by_their_vector_every_step(self, q):
        """Kung's direction/speed/timing: each element's per-step hop is
        exactly its stream's movement vector (one link, fixed direction)."""
        hs = HexSchedule(q=q)
        mv = hs.movement_vectors()
        for var in ("A", "B", "C"):
            for r in range(q):
                for s in range(q):
                    path = hex_element_positions(hs, var, r, s)
                    for (t0, n0), (t1, n1) in zip(path, path[1:]):
                        assert t1 == t0 + 1
                        assert (n1[0] - n0[0], n1[1] - n0[1]) == mv[var]

    def test_trace_counts_q_minus_1_hops_per_element(self, q):
        tr = trace_hex(HexSchedule(q=q))
        assert tr.words_total() == 3 * q * q * (q - 1)
        assert tr.num_steps == 3 * q - 2


class TestSimulation:
    @pytest.mark.parametrize("q", [1, 3, 6])
    def test_simulate_matches_reference(self, q):
        rng = np.random.default_rng(0)
        A, B = rng.normal(size=(q, q)), rng.normal(size=(q, q))
        hs = HexSchedule(q=q)
        np.testing.assert_allclose(hs.simulate(A, B), hs.reference(A, B),
                                   rtol=1e-12, atol=1e-12)

    def test_simulate_integer_exact(self):
        q = 4
        rng = np.random.default_rng(1)
        A = rng.integers(-5, 5, size=(q, q))
        B = rng.integers(-5, 5, size=(q, q))
        hs = HexSchedule(q=q)
        assert np.array_equal(hs.simulate(A, B), (A @ B).T)

    def test_systolic_properties_all_hold(self):
        assert all(HexSchedule(q=7).systolic_properties().values())
