"""Ring-TP MLP block == GSPMD reference, and its HLO uses permute chains."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.layers.ring_blocks import ring_mlp, gspmd_mlp_reference
from repro.roofline.hlo_stats import analyze

devs = np.array(jax.devices())
mesh = jax.make_mesh((4,), ("model",), devices=devs)
B, S, D, F = 2, 32, 16, 48
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (B, S, D), jnp.float32)
p = {
    "w_gate": jax.random.normal(jax.random.PRNGKey(1), (D, F), jnp.float32) * 0.1,
    "w_up": jax.random.normal(jax.random.PRNGKey(2), (D, F), jnp.float32) * 0.1,
    "w_down": jax.random.normal(jax.random.PRNGKey(3), (F, D), jnp.float32) * 0.1,
}
ref = gspmd_mlp_reference(p, x)

f = jax.jit(jax.shard_map(
    lambda xl, g, u, d: ring_mlp({"w_gate": g, "w_up": u, "w_down": d}, xl),
    mesh=mesh,
    in_specs=(P(None, "model", None), P(None, "model"), P(None, "model"),
              P("model", None)),
    out_specs=P(None, "model", None),
))
out = f(x, p["w_gate"], p["w_up"], p["w_down"])
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 2e-5, err

# the prescribed schedule: permute chains, no all-gather/all-reduce ops
txt = f.lower(x, p["w_gate"], p["w_up"], p["w_down"]).compile().as_text()
st = analyze(txt)
assert st.coll["collective-permute"] > 0, st.coll
assert st.coll["all-gather"] == 0 and st.coll["all-reduce"] == 0, st.coll
print("RING_BLOCK_OK")
"""


@pytest.mark.timeout(600)
def test_ring_mlp_matches_gspmd_and_uses_permutes():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                         text=True, env=env, timeout=590)
    assert "RING_BLOCK_OK" in res.stdout, res.stdout[-3000:] + res.stderr[-3000:]
