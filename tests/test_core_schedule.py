"""The paper's algebra: equivariant schedules, the solver, cost claims."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (TorusSchedule, Torus25DSchedule, cannon_schedule,
                        is_cannon_like, solve_torus, torus_hops)
from repro.core.cost import (bandwidth_lower_bound, cannon_comm_total,
                             schedule_25d_cost, torus_schedule_cost)
from repro.core.schedule import VAR_INDEX


class TestCannon:
    def test_embedding(self):
        assert cannon_schedule(5).is_embedding()

    def test_movements_are_paper_solution(self):
        cs = cannon_schedule(7)
        mv = cs.movements()
        assert mv["C"] == (0, 0)                      # C stationary
        assert torus_hops(mv["A"], 7) == 1            # A one hop per step
        assert torus_hops(mv["B"], 7) == 1            # B one hop per step

    def test_skewed_placement(self):
        """l_A from the solved diagram reproduces Cannon's classic skew
        A_ij -> P_{i, j-i} (up to the anchor)."""
        q = 5
        pl = cannon_schedule(q).placement("A")
        for i in range(q):
            for j in range(q):
                assert tuple(pl[i, j]) == (i, (j - i) % q)

    def test_validate(self):
        assert cannon_schedule(5).validate()

    def test_correct_execution(self):
        """Execute the schedule literally: every instruction at its (x,y,t)
        cell; verify C = A@B and the one-instruction-per-cell property."""
        q = 4
        cs = cannon_schedule(q)
        A = np.random.rand(q, q)
        B = np.random.rand(q, q)
        C = np.zeros((q, q))
        seen = set()
        for i in range(q):
            for j in range(q):
                for k in range(q):
                    cell = cs.f(i, j, k)
                    assert cell not in seen
                    seen.add(cell)
                    C[k, i] += A[i, j] * B[j, k]
        np.testing.assert_allclose(C, (A @ B).T, rtol=1e-10)


class TestSolver:
    def test_minimal_cost_is_two(self):
        """Paper Sec. 4.1: movement cost can vanish for at most one of
        A, B, C => the optimum is two one-hop movers."""
        sols = solve_torus(5)
        assert sols and sols[0].hop_cost == 2
        assert is_cannon_like(sols[0])

    def test_exact_cannon_recovered(self):
        q = 5
        cs = cannon_schedule(q)
        sols = solve_torus(q)
        assert any(s.schedule.M == cs.M for s in sols if s.hop_cost == 2)

    def test_at_most_one_stationary(self):
        from repro.core.solver import at_most_one_stationary
        assert at_most_one_stationary(3)

    @pytest.mark.parametrize("q", [3, 5])
    def test_all_solutions_valid(self, q):
        for sol in solve_torus(q, max_solutions=25):
            assert sol.schedule.validate()


@settings(max_examples=60, deadline=None)
@given(
    q=st.sampled_from([3, 5, 7]),
    rows=st.tuples(*[st.tuples(*[st.integers(-1, 1)] * 3)] * 3),
    i=st.integers(0, 6), j=st.integers(0, 6), k=st.integers(0, 6),
)
def test_equivariance_property(q, rows, i, j, k):
    """For ANY generator-image matrix M (valid or not as a schedule), the
    induced map is equivariant: f(sigma_1^a sigma_2^b sigma_3^c . x) =
    rho(...)^.. . f(x) -- i.e. f is linear in (i,j,k) over (Z_q^2, Z_t)."""
    sched = TorusSchedule(q=q, t=q, M=tuple(tuple(v % q for v in r) for r in rows))
    i, j, k = i % q, j % q, k % q
    base = sched.f(0, 0, 0)
    shifted = sched.f(i, j, k)
    (x1, y1, t1), (x2, y2, t2), (x3, y3, t3) = sched.M
    expect = (
        (base[0] + i * x1 + j * x2 + k * x3) % q,
        (base[1] + i * y1 + j * y2 + k * y3) % q,
        (base[2] + i * t1 + j * t2 + k * t3) % q,
    )
    assert shifted == expect


@settings(max_examples=40, deadline=None)
@given(q=st.sampled_from([3, 5]), var=st.sampled_from(["A", "B", "C"]))
def test_movement_consistency(q, var):
    """If a movement homomorphism exists, the data placement it induces is
    consistent: the variable needed by instruction (i,j,k) is at the
    instruction's processor at the instruction's time."""
    cs = cannon_schedule(q)
    mv = cs.movement(var)
    pl = cs.placement(var)
    (p0, p1), absent = VAR_INDEX[var]
    for i in range(q):
        for j in range(q):
            for k in range(q):
                x, y, t = cs.f(i, j, k)
                idx = (i, j, k)
                r, s = idx[p0], idx[p1]
                # position at time t = placement + t * mv
                px = (pl[r, s][0] + t * mv[0]) % q
                py = (pl[r, s][1] + t * mv[1]) % q
                assert (px, py) == (x, y)


class Test25D:
    def test_occupancy_and_reduction(self):
        s = Torus25DSchedule(q=8, c=2)
        cells = {}
        for i in range(8):
            for j in range(8):
                for k in range(8):
                    cells[s.f(i, j, k)] = cells.get(s.f(i, j, k), 0) + 1
        assert max(cells.values()) == 1
        # contraction slabs partition [q]
        slabs = [s.layer_contraction_slab(z) for z in range(2)]
        assert slabs == [(0, 4), (4, 8)]

    def test_comm_beats_cannon_when_memory_allows(self):
        n, q, c = 4096, 8, 4
        assert q % c == 0
        c25 = schedule_25d_cost(Torus25DSchedule(q=q, c=c), n)
        cannon = torus_schedule_cost(cannon_schedule(q), n)
        # per-node words should drop roughly by sqrt(c) (paper Sec. D.1)
        assert c25.words_per_node < cannon.words_per_node / c * q / q * 1.5


class TestLowerBounds:
    def test_cannon_within_constant_of_bound(self):
        n, p = 4096, 64
        M = n * n / p  # one block per variable (Cannon's memory regime)
        per_node = cannon_comm_total(n, p) / p
        lb = bandwidth_lower_bound(n, p, M)
        assert lb > 0
        assert per_node >= lb
        assert per_node <= 16 * lb  # constant-factor optimal
