"""repro.tune: the measured-autotuning search, table, and planner wiring.

Three layers of coverage:

  * search/table invariants -- every candidate MXU-aligned and
    VMEM-feasible (property test), bucket sharing, JSON round-trip with
    newer-schema rejection, profile embedding;
  * planner wiring -- a doctored table flips the strategy ranking and the
    overlap decision (the pinned regression that measured kernel seconds
    really enter ``calibrated_total_s``), tuned blocks land in the plan's
    ``TilingPlan``, the tuner participates in the plan-cache key;
  * the serving loop -- a subprocess Server warmup tunes each bucket's
    local shapes and the serve window runs at a 100% tuning-cache hit
    rate (the tuning twin of the plan-cache pin).
"""
import os
import subprocess
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=16")

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro import obs
from repro.kernels.matmul.kernel import vmem_working_set_bytes
from repro.obs.profile import MachineProfile, default_profile
from repro.plan import build_plan, rank_mesh_strategies
from repro.tune import (MXU, TunedBlocks, TuningTable, Tuner,
                        VMEM_BUDGET_BYTES, candidate_space, load_table,
                        pad_up, save_table, scaled_call_seconds,
                        shape_bucket, table_key, tune_shape)


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _entry(bucket, seconds, blocks=(128, 128, 128), order="zorder"):
    return TunedBlocks(block_m=blocks[0], block_n=blocks[1],
                       block_k=blocks[2], order=order, seconds=seconds,
                       bucket=bucket)


# --- candidate space -------------------------------------------------------


class TestCandidateSpace:
    @settings(max_examples=80, deadline=None)
    @given(m=st.integers(1, 4096), n=st.integers(1, 4096),
           k=st.integers(1, 4096), dtype_bytes=st.sampled_from([1, 2, 4]))
    def test_candidates_aligned_and_feasible(self, m, n, k, dtype_bytes):
        """Every searched candidate is MXU-aligned and fits the same VMEM
        budget ``default_blocks`` targets -- the search can never propose
        a block the kernel would spill on."""
        cands = candidate_space(m, n, k, dtype_bytes)
        assert cands
        for bm, bn, bk, order in cands:
            assert bm % MXU == 0 and bn % MXU == 0 and bk % MXU == 0
            assert order in ("zorder", "rowmajor")
            if min(m, n, k) >= MXU:  # tiny shapes get the canonical stub
                assert vmem_working_set_bytes(
                    bm, bn, bk, dtype_bytes) <= VMEM_BUDGET_BYTES
                assert bm <= pad_up(m) and bn <= pad_up(n) and bk <= pad_up(k)

    def test_tiny_shape_single_candidate(self):
        assert candidate_space(64, 32, 8) == ((MXU, MXU, MXU, "zorder"),)

    def test_max_candidates_bounds_deterministically(self):
        full = candidate_space(512, 512, 512, 2)
        sub = candidate_space(512, 512, 512, 2, max_candidates=6)
        assert len(sub) == 6 and set(sub) <= set(full)
        assert sub == candidate_space(512, 512, 512, 2, max_candidates=6)

    def test_fp32_space_no_larger_than_bf16(self):
        bf16 = candidate_space(4096, 4096, 4096, 2)
        fp32 = candidate_space(4096, 4096, 4096, 4)
        assert set(fp32) <= set(bf16)


# --- buckets and keys ------------------------------------------------------


class TestBuckets:
    def test_nearby_shapes_share_bucket(self):
        assert shape_bucket(300, 128, 200) == (512, 128, 256)
        assert table_key(300, 128, 200, "float32") == \
            table_key(290, 100, 140, "float32")

    def test_dtype_splits_key(self):
        assert table_key(256, 256, 256, "float32") != \
            table_key(256, 256, 256, "bfloat16")

    def test_scaled_call_seconds(self):
        e = _entry((512, 512, 512), 1.0)
        # a call with exactly half the padded FLOPs costs half the seconds
        assert scaled_call_seconds(e, 256, 512, 512) == pytest.approx(0.5)
        assert scaled_call_seconds(e, 512, 512, 512) == pytest.approx(1.0)


# --- table persistence -----------------------------------------------------


class TestTableJson:
    def _table(self):
        t = TuningTable(device_kind="cpu", created="2026-08-08")
        t = t.with_entry(256, 256, 256, "float32",
                         _entry((256, 256, 256), 1e-4, (256, 256, 256),
                                "rowmajor"))
        return t.with_entry(300, 128, 200, "bfloat16",
                            _entry((512, 128, 256), 5e-5))

    def test_round_trip(self, tmp_path):
        t = self._table()
        path = save_table(t, str(tmp_path / "t.json"))
        back = load_table(path)
        assert back == t
        assert back.lookup(290, 100, 140, "bfloat16").seconds == 5e-5

    def test_newer_schema_rejected(self):
        obj = self._table().to_json()
        obj["schema"] = 99
        with pytest.raises(ValueError, match="newer than supported"):
            TuningTable.from_json(obj)

    def test_lookup_counts_stats_without_breaking_hash(self):
        t = self._table()
        h0 = hash(t)
        assert t.lookup(256, 256, 256, "float32") is not None
        assert t.lookup(64, 64, 64, "float32") is None
        assert t.stats == {"hits": 1, "misses": 1}
        assert hash(t) == h0  # stats excluded from eq/hash

    def test_profile_embedding_round_trip(self, tmp_path):
        prof = default_profile()
        import dataclasses

        prof = dataclasses.replace(prof, tuning=self._table())
        obj = prof.to_json()
        back = MachineProfile.from_json(obj)
        assert back.tuning is not None
        assert back.tuning.lookup(256, 256, 256, "float32",
                                  count=False).order == "rowmajor"
        # pre-tuning profile JSONs still load (tuning stays None)
        del obj["tuning"]
        assert MachineProfile.from_json(obj).tuning is None


# --- the search itself -----------------------------------------------------


class TestSearch:
    def test_tune_shape_returns_feasible_winner(self):
        e = tune_shape(64, 64, 64, "float32", reps=1, interpret=True)
        assert (e.block_m, e.block_n, e.block_k) == (MXU, MXU, MXU)
        assert e.seconds > 0 and e.bucket == (128, 128, 128)

    def test_tuner_searches_once_per_bucket(self):
        tuner = Tuner(reps=1, max_candidates=2, interpret=True)
        e1 = tuner.entry_for(64, 64, 64, dtype="float32")
        e2 = tuner.entry_for(100, 90, 120, dtype="float32")  # same bucket
        assert e1 is e2
        assert tuner.stats["searches"] == 1
        assert tuner.stats["hits"] == 1 and tuner.stats["misses"] == 1
        assert tuner.compute_seconds(64, 64, 64, dtype="float32") > 0
        assert tuner.stats["searches"] == 1  # cached, no re-search

    def test_tuner_table_snapshot(self):
        tuner = Tuner(reps=1, max_candidates=2, interpret=True,
                      device_kind="cpu")
        tuner.entry_for(64, 64, 64, dtype="float32")
        table = tuner.table()
        assert table.device_kind == "cpu" and len(table.entries) == 1
        assert table.lookup(64, 64, 64, "float32", count=False) is not None


# --- planner wiring --------------------------------------------------------


def _mesh(shape, names, need):
    devs = jax.devices()
    if len(devs) < need:
        pytest.skip(f"needs {need} forced-host devices, have {len(devs)}")
    return jax.make_mesh(shape, names, devices=devs[:need])


class TestPlannerWiring:
    def test_doctored_table_flips_strategy(self):
        """The pinned regression: on a 4x4 mesh at 4096^3 the analytic
        model picks cannon; a tuning table claiming cannon's local bucket
        (1024^3) is slow and summa's (1024x1024x256) is ~free must flip
        the calibrated ranking to summa -- measured kernel seconds really
        drive ``calibrated_total_s``."""
        mesh = _mesh((4, 4), ("x", "y"), 16)
        m = n = k = 4096
        assert rank_mesh_strategies(m, n, k, mesh)[0].strategy == "cannon"
        tbl = TuningTable(device_kind="cpu")
        tbl = tbl.with_entry(1024, 1024, 1024, "float32",
                             _entry((1024, 1024, 1024), 10.0))
        tbl = tbl.with_entry(1024, 1024, 256, "float32",
                             _entry((1024, 1024, 256), 1e-9))
        ranked = rank_mesh_strategies(m, n, k, mesh, tuning=tbl,
                                      dtype="float32")
        assert ranked[0].strategy == "summa"
        plan = build_plan(m, n, k, mesh=mesh, strategy=None, batch=(),
                          a_dtype="float32", b_dtype="float32",
                          out_dtype=None, tuning=tbl, use_cache=False)
        assert plan.strategy == "summa"
        assert plan.tiling.tuned  # doctored blocks folded into the tiling

    def test_doctored_table_flips_overlap(self):
        """Zero measured compute leaves nothing to hide the collectives
        behind: the overlap resolver must fall back to staged."""
        mesh = _mesh((2, 2), ("x", "y"), 4)
        m = n = k = 4096
        kw = dict(mesh=mesh, strategy="cannon", batch=(),
                  a_dtype="float32", b_dtype="float32", out_dtype=None,
                  use_cache=False)
        assert build_plan(m, n, k, **kw).overlap is True
        tbl = TuningTable(device_kind="cpu").with_entry(
            2048, 2048, 2048, "float32", _entry((2048, 2048, 2048), 0.0))
        assert build_plan(m, n, k, tuning=tbl, **kw).overlap is False

    def test_tuned_blocks_consumed_by_tiling(self):
        mesh = _mesh((2, 2), ("x", "y"), 4)
        tbl = TuningTable(device_kind="cpu").with_entry(
            256, 256, 256, "float32",
            _entry((256, 256, 256), 1e-4, (128, 128, 256), "rowmajor"))
        plan = build_plan(512, 512, 512, mesh=mesh, strategy="cannon",
                          batch=(), a_dtype="float32", b_dtype="float32",
                          out_dtype=None, tuning=tbl, use_cache=False)
        t = plan.tiling
        assert t.tuned and t.order == "rowmajor"
        assert (t.block_m, t.block_n, t.block_k) == (128, 128, 256)

    def test_local_plan_uses_tuned_blocks(self):
        tbl = TuningTable(device_kind="cpu").with_entry(
            256, 256, 256, "float32",
            _entry((256, 256, 256), 1e-4, (256, 128, 128), "rowmajor"))
        plan = build_plan(256, 256, 256, mesh=None, strategy=None,
                          batch=(), a_dtype="float32", b_dtype="float32",
                          out_dtype=None, tuning=tbl, use_cache=False)
        assert plan.strategy == "local" and plan.tiling.tuned
        assert plan.tiling.block_m == 256

    def test_tuning_in_plan_cache_key(self):
        from repro.plan import plan_cache

        tbl = TuningTable(device_kind="cpu").with_entry(
            256, 256, 256, "float32",
            _entry((256, 256, 256), 1e-4, (128, 128, 256), "rowmajor"))
        kw = dict(mesh=None, strategy=None, batch=(), a_dtype="float32",
                  b_dtype="float32", out_dtype=None)
        p0 = build_plan(256, 256, 256, **kw)
        p1 = build_plan(256, 256, 256, tuning=tbl, **kw)
        assert not p0.tiling.tuned and p1.tiling.tuned
        # distinct cache entries: re-lookups return the right plan
        assert build_plan(256, 256, 256, **kw) is p0
        assert build_plan(256, 256, 256, tuning=tbl, **kw) is p1

    def test_explicit_tiling_beats_table(self):
        from repro.plan import TilingPlan

        tbl = TuningTable(device_kind="cpu").with_entry(
            256, 256, 256, "float32",
            _entry((256, 256, 256), 1e-4, (128, 128, 256), "rowmajor"))
        plan = build_plan(256, 256, 256, mesh=None, strategy=None, batch=(),
                          a_dtype="float32", b_dtype="float32",
                          out_dtype=None, tuning=tbl, use_cache=False,
                          tiling=TilingPlan(block_m=128))
        assert not plan.tiling.tuned and plan.tiling.block_m == 128


# --- pad-waste metric ------------------------------------------------------


def test_pad_waste_metric_recorded():
    import jax.numpy as jnp

    from repro.kernels.matmul import matmul

    a = jnp.ones((300, 128), jnp.float32)
    b = jnp.ones((128, 128), jnp.float32)
    with obs.observe() as rec:
        matmul(a, b, block_m=256, interpret=True)
    snap = obs.metrics_snapshot(rec)
    waste = snap["metrics"]["kernel.pad_waste"]
    # m=300 pads to 512 under block_m=256; n and k are exact
    assert waste["count"] == 1
    assert waste["mean"] == pytest.approx(512 / 300)


# --- serve warmup tunes, serve window hits ---------------------------------

_TUNE_SERVE_SCRIPT = r"""
import dataclasses, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.runtime.serve import ServeConfig
from repro.serve import warmup
from repro.tune import Tuner

devs = jax.devices()
mesh = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"), dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
scfg = ServeConfig(max_new_tokens=4, max_seq=64)

tuner = Tuner(reps=1, max_candidates=2, interpret=True)
srv = warmup(model, params, scfg, mesh=mesh, buckets=[(2, 8)], tuning=tuner)
assert tuner.stats["searches"] > 0, tuner.stats  # warmup tuned the buckets

r = srv.generate([[5, 6, 7], [9, 2, 3, 4, 1]])
rep = srv.cache_report()
assert rep["serve_window"]["hit_rate"] == 1.0, rep
# no serve-window search: every tuning lookup hit the warmup entries
tw = rep["tuning"]["serve_window"]
assert tw["misses"] == 0 and tw["hit_rate"] == 1.0, rep["tuning"]
assert r.plan_probe["tune_probed"] > 0, r.plan_probe
assert r.plan_probe["tune_missing"] == 0, r.plan_probe
assert rep["tuning"]["entries"] > 0
searches_before = tuner.stats["searches"]
srv.generate([[4, 4], [7, 7, 7]])
assert tuner.stats["searches"] == searches_before  # still no search
print("TUNE_SERVE_OK")
"""


@pytest.mark.timeout(600)
def test_serve_warmup_tunes_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _TUNE_SERVE_SCRIPT], capture_output=True,
        text=True, env=env, timeout=590)
    assert "TUNE_SERVE_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
