"""HLO static analyzer: scan multipliers, collective accounting, terms."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import Roofline
from repro.roofline.hlo_stats import analyze, _shape_elems_bytes


def test_scan_flops_multiplied():
    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    L, m, d = 8, 128, 256
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, d), jnp.float32),
        jax.ShapeDtypeStruct((L, d, d), jnp.float32),
    ).compile()
    c = analyze(comp.as_text())
    analytic = 2 * m * d * d * L
    assert 0.9 < c.flops / analytic < 1.3

    # cross-check: XLA's own cost_analysis undercounts by exactly 1/L
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca.get("flops", 0) < c.flops / 2


def test_nested_scan():
    def f(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=4)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((3, 64, 64), jnp.float32),
    ).compile()
    c = analyze(comp.as_text())
    analytic = 2 * 64 * 64 * 64 * 3 * 4
    assert 0.9 < c.flops / analytic < 1.5


def test_shape_parse():
    elems, bytes_ = _shape_elems_bytes("bf16[256,4096]{1,0}")
    assert elems == 256 * 4096 and bytes_ == elems * 2
    elems, bytes_ = _shape_elems_bytes("(s32[], f32[8,8]{1,0})")
    assert bytes_ == 4 + 64 * 4


def test_collective_parse_handcrafted():
    hlo = """
HloModule m
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %ag = f32[128,64]{1,0} all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[128,64]{1,0} all-reduce(%ag), to_apply=%add
  %cp = f32[128,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  ROOT %sl = f32[64,64]{1,0} slice(%cp), slice={[0:64], [0:64]}
}
"""
    c = analyze(hlo)
    assert c.coll["all-gather"] == 128 * 64 * 4
    assert c.coll["all-reduce"] == 128 * 64 * 4
    assert c.coll["collective-permute"] == 128 * 64 * 4


def test_roofline_terms_and_dominant():
    r = Roofline(flops=1e15, hbm_bytes=1e12, coll_bytes=1e10,
                 coll_by_kind={}, model_flops=2.56e17, chips=256)
    assert r.compute_s == pytest.approx(1e15 / 197e12)
    assert r.memory_s == pytest.approx(1e12 / 819e9)
    assert r.collective_s == pytest.approx(1e10 / 50e9)
    assert r.dominant == "compute"
    assert 0 < r.roofline_fraction <= 1.0 + 1e-6


def test_psum_collective_counted_with_shardmap():
    """End-to-end: a sharded psum program shows all-reduce bytes."""
    import subprocess, sys, os
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.roofline.hlo_stats import analyze
mesh = jax.make_mesh((4,), ("d",))
f = jax.shard_map(lambda x: jax.lax.psum(x, "d"), mesh=mesh,
                  in_specs=P("d"), out_specs=P())
comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
c = analyze(comp.as_text())
assert c.coll["all-reduce"] > 0, c.coll
print("PSUM_OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=300)
    assert "PSUM_OK" in res.stdout, res.stdout + res.stderr
