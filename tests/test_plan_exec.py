"""Plan engine vs legacy executors on fake devices (acceptance criteria).

Asserts, per strategy on a 4-device CPU mesh (8 devices for the 2.5D
family):

  * ``build_plan`` -> ``lower_shard_map``, ``symmetric_matmul(strategy=...)``
    and the strategy entry points (``cannon_matmul``, ...) all produce
    bitwise-identical outputs -- the entry points are facades over the plan
    engine, so this pins that every dispatch route builds the same plan
    (axes defaults, padding, specs), while the XLA-oracle comparison below
    pins the lowering's numerics themselves;
  * batched inputs (leading batch dims, none of which the pre-plan
    executors handled) and ragged m/n/k match the XLA oracle;
  * bf16 in / fp32 accumulation out holds on every strategy;
  * a repeated identical call hits the plan cache (stats counter);
  * the layer library routes through the plan engine inside
    ``planned_matmuls``.

Runs in a subprocess so the main pytest process keeps the 1-device view.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import (cannon_matmul, summa_matmul, pod25d_matmul,
                        cannon25d_matmul, symmetric_matmul)
from repro import plan as planlib
from repro.plan import build_plan, execute_plan, lower_shard_map

devs = np.array(jax.devices())
mesh22 = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
mesh1d = jax.make_mesh((4,), ("t",), devices=devs[:4])
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "x", "y"), devices=devs[:8])

M, K, N = 32, 24, 16
a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
ref = np.asarray(a) @ np.asarray(b)
tol = 3e-5

legacy = {
    "cannon": (mesh22, functools.partial(cannon_matmul, mesh=mesh22)),
    "summa": (mesh22, functools.partial(summa_matmul, mesh=mesh22)),
    "pod25d": (mesh3, functools.partial(pod25d_matmul, mesh=mesh3)),
    "cannon25d": (mesh3, functools.partial(cannon25d_matmul, mesh=mesh3)),
    "ring_ag": (mesh1d, None),
    "ring_rs": (mesh1d, None),
}

for strat, (mesh, legacy_fn) in legacy.items():
    via_sym = symmetric_matmul(a, b, mesh=mesh, strategy=strat)
    plan = build_plan(M, N, K, mesh=mesh, strategy=strat,
                      a_dtype=a.dtype, b_dtype=b.dtype)
    via_plan = lower_shard_map(plan)(a, b)
    assert np.array_equal(np.asarray(via_sym), np.asarray(via_plan)), \
        f"{strat}: symmetric_matmul != lower_shard_map(build_plan)"
    if legacy_fn is not None:
        via_legacy = legacy_fn(a, b)
        assert np.array_equal(np.asarray(via_legacy), np.asarray(via_plan)), \
            f"{strat}: legacy executor != plan lowering"
    err = float(np.max(np.abs(np.asarray(via_plan) - ref)))
    assert err < tol, f"{strat}: err {err} vs oracle"

# --- flattened multi-axis ring: the default cost-model outcome on 2-D
# meshes with a dominant contraction dim must actually execute ------------
from repro.dist.api import choose
ak = jax.random.normal(jax.random.PRNGKey(8), (16, 512), jnp.float32)
bk = jax.random.normal(jax.random.PRNGKey(9), (512, 16), jnp.float32)
assert choose(16, 16, 512, mesh=mesh22) == "ring_rs"
out = symmetric_matmul(ak, bk, mesh=mesh22)  # auto-dispatch, tuple ring axis
err = float(np.max(np.abs(np.asarray(out) - np.asarray(ak) @ np.asarray(bk))))
assert err < 2e-4, f"flattened-ring auto dispatch: err {err}"
out_ag = symmetric_matmul(a, b, mesh=mesh22, strategy="ring_ag")
err = float(np.max(np.abs(np.asarray(out_ag) - ref)))
assert err < tol, f"flattened ring_ag on 2-axis mesh: err {err}"

# --- plan cache: second identical dispatch must hit -------------------------
planlib.cache_clear()
symmetric_matmul(a, b, mesh=mesh22, strategy="cannon")
s0 = planlib.cache_stats()
symmetric_matmul(a, b, mesh=mesh22, strategy="cannon")
s1 = planlib.cache_stats()
assert s1["hits"] == s0["hits"] + 1 and s1["misses"] == s0["misses"], (s0, s1)

# --- batched inputs through every strategy ----------------------------------
B, S = 3, 10
xb = jax.random.normal(jax.random.PRNGKey(2), (B, S, K), jnp.float32)
bref = np.einsum("bmk,kn->bmn", np.asarray(xb), np.asarray(b))
for strat, (mesh, _) in legacy.items():
    out = symmetric_matmul(xb, b, mesh=mesh, strategy=strat)
    assert out.shape == (B, S, N), (strat, out.shape)
    err = float(np.max(np.abs(np.asarray(out) - bref)))
    assert err < tol, f"batched {strat}: err {err}"
# batched == hand-folded, bitwise (folding is the defined lowering)
flat = symmetric_matmul(xb.reshape(B * S, K), b, mesh=mesh22,
                        strategy="cannon").reshape(B, S, N)
bat = symmetric_matmul(xb, b, mesh=mesh22, strategy="cannon")
assert np.array_equal(np.asarray(bat), np.asarray(flat))
# batched-both
b3 = jax.random.normal(jax.random.PRNGKey(3), (B, K, N), jnp.float32)
out = symmetric_matmul(xb, b3, mesh=mesh22, strategy="cannon")
err = float(np.max(np.abs(np.asarray(out)
                          - np.einsum("bmk,bkn->bmn", np.asarray(xb),
                                      np.asarray(b3)))))
assert err < tol, f"batched-both: {err}"

# --- ragged shapes (m/n/k not divisible by any mesh side) -------------------
ar = jax.random.normal(jax.random.PRNGKey(4), (13, 11), jnp.float32)
br = jax.random.normal(jax.random.PRNGKey(5), (11, 7), jnp.float32)
rref = np.asarray(ar) @ np.asarray(br)
for strat, (mesh, _) in legacy.items():
    out = symmetric_matmul(ar, br, mesh=mesh, strategy=strat)
    assert out.shape == (13, 7)
    err = float(np.max(np.abs(np.asarray(out) - rref)))
    assert err < tol, f"ragged {strat}: err {err}"

# --- dtype promotion: bf16 in, fp32 accumulate out --------------------------
abf, bbf = a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
for strat, (mesh, _) in legacy.items():
    out = symmetric_matmul(abf, bbf, mesh=mesh, strategy=strat,
                           out_dtype=jnp.float32)
    assert out.dtype == jnp.float32, (strat, out.dtype)
    err = float(np.max(np.abs(np.asarray(out) - ref)))
    assert err < 0.5, f"bf16 {strat}: err {err}"
    # default out dtype follows the operands
    assert symmetric_matmul(abf, bbf, mesh=mesh,
                            strategy=strat).dtype == jnp.bfloat16

# --- layers route through the plan engine inside planned_matmuls ------------
from repro.layers.mlp import mlp, mlp_params
from repro.plan import planned_matmuls

p = mlp_params(jax.random.PRNGKey(6), 16, 32, dtype=jnp.float32)
x3 = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 16), jnp.float32)
base = mlp(p, x3)
planlib.cache_clear()
with planned_matmuls(mesh1d):
    planned = mlp(p, x3)
assert planlib.cache_stats()["misses"] > 0, "layers did not consult the plan"
err = float(np.max(np.abs(np.asarray(planned) - np.asarray(base))))
assert err < 1e-4, f"planned mlp diverges: {err}"

print("PLAN_EXEC_OK")
"""


@pytest.mark.timeout(600)
def test_plan_execution_consistency_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=590,
    )
    assert "PLAN_EXEC_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
    )


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
