"""Shared test configuration.

Puts ``src/`` on sys.path (so ``python -m pytest`` works without the
PYTHONPATH export) and, when the real ``hypothesis`` package is not
installed, registers the in-repo deterministic fallback so the property
tests still collect and run (see src/repro/_hypothesis_stub.py; the real
package is the declared dev-dependency and wins when present).
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._hypothesis_stub import install

    install()
