"""Shared test configuration.

Puts ``src/`` on sys.path (so ``python -m pytest`` works without the
PYTHONPATH export) and, when the real ``hypothesis`` package is not
installed, registers the in-repo deterministic fallback so the property
tests still collect and run (see src/repro/_hypothesis_stub.py; the real
package is the declared dev-dependency and wins when present).
"""
import os
import sys

import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._hypothesis_stub import install

    install()


@pytest.fixture(autouse=True)
def _reset_plan_cache():
    """Isolate the process-global plan cache between tests: entries AND
    hit/miss counters start fresh, so cache-stats assertions (test_plan)
    cannot couple to whichever test planned first."""
    from repro.plan import cache_clear

    cache_clear()
    yield
