"""End-to-end behaviour tests for the system."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    res = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, timeout=590, cwd=_ROOT,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "C == A@B: True" in res.stdout
    assert "cannon-like: True" in res.stdout


def test_train_example_loss_decreases():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    res = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", "train_lm.py"),
         "--preset", "demo", "--steps", "40", "--batch", "4", "--seq", "128"],
        capture_output=True, text=True, env=env, timeout=590, cwd=_ROOT,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    # parse "first logged loss X -> last Y  (restarts: N)"
    line = [l for l in res.stdout.splitlines() if "first logged loss" in l][0]
    first = float(line.split("loss")[1].split("->")[0])
    last = float(line.split("-> last")[1].split("(")[0])
    assert last < first


def test_serve_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    res = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", "serve_batched.py"),
         "--max-new", "6", "--batch", "2"],
        capture_output=True, text=True, env=env, timeout=590, cwd=_ROOT,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    # plan-routed serving: warm bucket hit + throughput line
    assert "tok/s" in res.stdout
    assert "bucket=4x16" in res.stdout
    assert "hit rate 1.0" in res.stdout


def test_dryrun_entry_single_cell():
    """The multi-pod dry-run machinery end-to-end for one (arch, shape) on
    both meshes (the full 33x2-cell sweep is run separately; this keeps the
    harness honest in CI)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-350m", "--shape", "decode_32k",
         "--out", "/tmp/dryrun_ci.json"],
        capture_output=True, text=True, env=env, timeout=590, cwd=_ROOT,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "2/2 cells compiled" in res.stdout, res.stdout[-2000:]


def test_elastic_remesh_state_roundtrip():
    """Simulated pod loss: state built for a (2, 2) mesh re-placed onto the
    survivor mesh; values preserved."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.elastic import make_mesh, shrink_after_failure, replace_state
state = {
    "step": jnp.int32(7),
    "master": {"wq": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)},
    "m": {"wq": jnp.ones((8, 8), jnp.float32)},
    "v": {"wq": jnp.ones((8, 8), jnp.float32)},
}
mesh2 = make_mesh((2, 2, 2), ("pod", "data", "model"))
st2 = replace_state(state, mesh2)
surv = shrink_after_failure(mesh2, lost_pod=1)
assert "pod" not in surv.axis_names and surv.devices.size == 4
st1 = replace_state(st2, surv)
np.testing.assert_array_equal(np.asarray(st1["master"]["wq"]),
                              np.asarray(state["master"]["wq"]))
print("ELASTIC_OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=590)
    assert "ELASTIC_OK" in res.stdout, res.stdout + res.stderr
