"""repro.obs: span tracing, metrics, exporters, machine profiles.

Pins the PR-6 acceptance criteria:

  * span nesting + Perfetto trace_event export round-trips (schema keys,
    JSON-serializable, nesting depths);
  * disabled mode is a true no-op (shared singleton span, empty recorder);
  * the obs collective multiset equals the ``repro.verify`` interceptor's
    AND the schedule trace's, per strategy, on real executions (subprocess
    with forced-host devices);
  * ``rank_mesh_strategies(profile=default_profile())`` reproduces the
    analytic ranking exactly, and a synthetic latency-dominated profile
    flips cannon -> summa (the calibrated-ranking regression test);
  * profile JSON round-trip + newer-schema rejection, α–β fit recovery;
  * plan-cache ``cache_info()`` size/eviction accounting.
"""
import json
import math
import os
import subprocess
import sys
from collections import Counter
from types import SimpleNamespace

import numpy as np
import pytest

from repro import obs
from repro.obs.profile import (LinkParams, MachineProfile, default_profile,
                               fit_alpha_beta, load_profile, save_profile)
from repro.plan import PlanCache, rank_mesh_strategies
from repro.plan.cache import plan_cache


def fake_mesh(sizes, names):
    total = math.prod(sizes)
    return SimpleNamespace(
        axis_names=tuple(names),
        shape=dict(zip(names, sizes)),
        size=total,
        devices=np.array([SimpleNamespace(id=i, platform="cpu")
                          for i in range(total)]),
    )


# --- spans / recorder --------------------------------------------------------


def test_disabled_mode_is_noop():
    assert not obs.enabled()
    s1 = obs.span("a", x=1)
    s2 = obs.span("b")
    assert s1 is s2 is obs.NOOP_SPAN  # shared singleton, no allocation
    with s1:
        obs.record_collective("ppermute", 4, 64, perm=[(0, 1), (1, 0)])
        obs.instant("nothing")
        assert obs.current_tags() == {}
    rec = obs.get_recorder()
    assert rec.spans == [] and rec.collectives == [] and rec.instants == []


def test_span_nesting_and_tags():
    with obs.observe() as rec:
        with obs.span("outer", strategy="cannon", m=8):
            with obs.span("inner", m=16):
                assert obs.current_tags() == {"strategy": "cannon", "m": 16}
            with obs.span("inner"):
                pass
    names = [s.name for s in rec.spans]
    assert names == ["inner", "inner", "outer"]  # exit order
    depths = {s.name: s.depth for s in rec.spans}
    assert depths == {"inner": 1, "outer": 0}
    assert rec.span_counts() == {"inner": 2, "outer": 1}
    outer = next(s for s in rec.spans if s.name == "outer")
    inner = next(s for s in rec.spans if s.name == "inner")
    assert outer.dur_us >= inner.dur_us >= 0
    # observe() restored the previous (disabled) state
    assert not obs.enabled()


def test_collective_events_carry_strategy_and_key():
    with obs.observe() as rec:
        with obs.span("plan.execute", strategy="summa"):
            obs.record_collective("all_gather", 4, 128)
            obs.record_collective("ppermute", 4, 64,
                                  perm=[(1, 0), (0, 1), (2, 2)])
    ag, pp = rec.collectives
    assert ag.strategy == "summa" and pp.strategy == "summa"
    assert ag.key == ("all_gather", 4, 128, None)
    # identity pairs dropped, rest sorted -- verify's canonical form
    assert pp.key == ("ppermute", 4, 64, ((0, 1), (1, 0)))
    ms = obs.collective_multiset(rec, strategy="summa")
    assert ms == Counter([ag.key, pp.key])
    assert obs.collective_multiset(rec, strategy="cannon") == Counter()


def test_trace_export_perfetto_roundtrip(tmp_path):
    with obs.observe() as rec:
        with obs.span("plan.build", strategy="cannon", m=8, n=8, k=8):
            with obs.span("plan.lower", strategy="cannon"):
                obs.record_collective("ppermute", 4, 16, perm=[(0, 1)])
        obs.instant("plan.built", strategy="cannon")
    doc = obs.to_trace_events(rec)
    assert doc["otherData"]["schema"] == obs.SCHEMA_VERSION
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    inst = [e for e in events if e["ph"] == "i"]
    assert {e["name"] for e in xs} == {"plan.build", "plan.lower"}
    assert "collective.ppermute" in {e["name"] for e in inst}
    for e in xs:  # Perfetto complete-event required keys
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["dur"] >= 0
    coll = next(e for e in inst if e["name"] == "collective.ppermute")
    assert coll["args"]["strategy"] == "cannon"
    assert coll["args"]["shard_words"] == 16
    # file round-trip stays valid JSON with identical events
    p = tmp_path / "trace.json"
    obs.write_trace(str(p), rec)
    assert json.loads(p.read_text())["traceEvents"] == json.loads(
        json.dumps(events))


def test_metrics_counters_and_histograms():
    obs.reset_metrics()
    c = obs.counter("test.count")
    c.inc()
    c.inc(2, strategy="cannon")
    assert c.total() == 3
    h = obs.histogram("test.us")
    for v in (1.0, 3.0, 5.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["sum"] == 9.0
    assert s["min"] == 1.0 and s["max"] == 5.0
    snap = obs.snapshot()
    assert any(k.startswith("test.count") for k in snap)
    assert snap["test.us"]["mean"] == 3.0
    obs.reset_metrics()
    assert obs.counter("test.count").total() == 0


def test_metrics_snapshot_envelope():
    obs.reset_metrics()
    with obs.observe() as rec:
        with obs.span("plan.execute", strategy="ring_ag"):
            obs.record_collective("ppermute", 4, 32, perm=[(0, 1)])
    snap = obs.metrics_snapshot(rec)
    assert snap["schema"] == obs.SCHEMA_VERSION
    assert snap["spans"] == {"plan.execute": 1}
    assert snap["collectives"]["ring_ag"]["ppermute"]["count"] == 1
    assert snap["collectives"]["ring_ag"]["ppermute"]["shard_words"] == 32


# --- machine profiles / calibrated ranking -----------------------------------


def test_profile_json_roundtrip(tmp_path):
    # links sorted by class name -- the canonical (from_json) order
    prof = MachineProfile(
        platform="cpu", peak_flops=1e12,
        links=(("axis:x", LinkParams(2e-6, 5e9)),
               ("ici", LinkParams(1e-6, 1e10))),
        created="2026-08-08T00:00:00Z")
    p = tmp_path / "machine_profile.json"
    save_profile(prof, str(p))
    back = load_profile(str(p))
    assert back == prof
    assert back.link("axis:x").alpha_s == 2e-6
    assert back.link("missing") is back.links[0][1]  # first-class fallback


def test_profile_rejects_newer_schema():
    with pytest.raises(ValueError, match="newer"):
        MachineProfile.from_json(
            {"schema": 99, "peak_flops": 1.0, "links": {}})


def test_fit_alpha_beta_recovers_parameters():
    alpha, bw = 5e-6, 2e9
    sizes = [1 << 14, 1 << 17, 1 << 20, 1 << 22]
    times = [alpha + s / bw for s in sizes]
    lp = fit_alpha_beta(sizes, times)
    assert lp.alpha_s == pytest.approx(alpha, rel=1e-6)
    assert lp.bw_bytes_per_s == pytest.approx(bw, rel=1e-6)
    # degenerate single point: everything attributed to bandwidth
    one = fit_alpha_beta([1 << 20], [1e-3])
    assert one.alpha_s == 0.0 and one.bw_bytes_per_s > 0


def test_default_profile_matches_analytic_ranking():
    mesh = fake_mesh((4, 4), ("x", "y"))
    for m, n, k in ((4096, 4096, 4096), (64, 1024, 64), (256, 256, 1 << 16)):
        analytic = [e.strategy for e in rank_mesh_strategies(m, n, k, mesh)]
        calibrated = [e.strategy for e in rank_mesh_strategies(
            m, n, k, mesh, profile=default_profile())]
        assert calibrated == analytic, (m, n, k)


def test_latency_profile_flips_cannon_to_summa():
    """The calibrated-ranking regression test: a latency-dominated machine
    (huge α, effectively infinite bandwidth/compute) must prefer the
    fewer-rounds schedule -- summa (qx-1)+(qy-1)=6 rounds beats cannon
    2q=8 on 4x4 -- while the analytic (bandwidth-only) model prefers
    cannon."""
    mesh = fake_mesh((4, 4), ("x", "y"))
    m = n = k = 4096
    analytic_top = rank_mesh_strategies(m, n, k, mesh)[0].strategy
    assert analytic_top == "cannon"
    latency = MachineProfile(
        platform="synth", peak_flops=1e18,
        links=(("ici", LinkParams(1.0, 1e18)),))
    ranked = rank_mesh_strategies(m, n, k, mesh, profile=latency)
    assert ranked[0].strategy == "summa"
    by_strategy = {e.strategy: e for e in ranked}
    assert latency.seconds(by_strategy["summa"]) < \
        latency.seconds(by_strategy["cannon"])
    # the estimates themselves (the conformance-checked word counts) are
    # identical to the analytic run -- only the sort key changed
    assert {e.strategy: e.comm_bytes for e in ranked} == \
        {e.strategy: e.comm_bytes
         for e in rank_mesh_strategies(m, n, k, mesh)}


def test_build_plan_profile_in_cache_key():
    from repro.plan import build_plan

    mesh = fake_mesh((4, 4), ("x", "y"))
    plan_cache.clear()
    latency = MachineProfile(
        platform="synth", peak_flops=1e18,
        links=(("ici", LinkParams(1.0, 1e18)),))
    p_analytic = build_plan(4096, 4096, 4096, mesh=mesh)
    p_latency = build_plan(4096, 4096, 4096, mesh=mesh, profile=latency)
    assert p_analytic.strategy == "cannon"
    assert p_latency.strategy == "summa"
    assert plan_cache.info()["misses"] == 2  # distinct cache entries
    assert build_plan(4096, 4096, 4096, mesh=mesh,
                      profile=latency).strategy == "summa"
    assert plan_cache.info()["hits"] == 1


# --- plan cache accounting ---------------------------------------------------


def test_cache_info_eviction_accounting():
    c = PlanCache(max_entries=2)
    assert c.get("a") is None
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1
    c.put("b", 3)  # overwrite: no eviction
    assert c.info()["evictions"] == 0
    c.put("c", 4)  # capacity hit: oldest ("a") dropped
    info = c.info()
    assert info["evictions"] == 1
    assert info["currsize"] == 2 and info["maxsize"] == 2
    assert c.get("a") is None  # evicted
    assert info["hits"] == 1 and info["misses"] == 1
    c.clear()
    assert c.info() == {"hits": 0, "misses": 0, "currsize": 0,
                        "maxsize": 2, "evictions": 0}


def test_report_plan_cache_table():
    from repro.launch.report import plan_cache_table

    table = plan_cache_table({"hits": 3, "misses": 1, "currsize": 1,
                              "maxsize": 1024, "evictions": 0})
    assert "| 3 | 1 | 0.75 | 1 | 1024 | 0 |" in table


# --- obs == interceptor == trace on real executions (subprocess) -------------

_TRIANGLE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from collections import Counter

from repro import obs
from repro.plan import build_plan
from repro.plan.lower_shard_map import _lower_shard_map
from repro.verify.interceptor import intercept
from repro.verify.trace import trace_plan

devs = np.array(jax.devices())
mesh22 = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
mesh1d = jax.make_mesh((4,), ("t",), devices=devs[:4])
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "x", "y"), devices=devs[:8])
cells = [("cannon", mesh22), ("summa", mesh22), ("ring_ag", mesh1d),
         ("ring_rs", mesh1d), ("cannon25d", mesh3), ("pod25d", mesh3)]

m, n, k = 24, 16, 32
a = jnp.ones((m, k), jnp.float32)
b = jnp.ones((k, n), jnp.float32)
for strat, mesh in cells:
    plan = build_plan(m, n, k, mesh=mesh, strategy=strat, use_cache=False)
    with obs.observe() as rec:
        with intercept() as cap:  # both observers active simultaneously
            with obs.span("plan.execute", strategy=strat):
                jax.block_until_ready(_lower_shard_map(plan)(a, b))
    obs_ms = obs.collective_multiset(rec, strategy=strat)
    int_ms = Counter(r.key for r in cap.records)
    trace_ms = Counter(r.key for r in trace_plan(plan).records)
    assert len(int_ms) > 0, f"{strat}: interceptor saw nothing"
    assert obs_ms == int_ms == trace_ms, (
        f"{strat}: obs={sorted(obs_ms.items())} "
        f"interceptor={sorted(int_ms.items())} "
        f"trace={sorted(trace_ms.items())}")
    assert all(ev.strategy == strat for ev in rec.collectives), strat
print("OBS_TRIANGLE_OK")
"""


@pytest.mark.timeout(600)
def test_obs_multiset_matches_interceptor_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _TRIANGLE_SCRIPT], capture_output=True,
        text=True, env=env, timeout=590,
    )
    assert "OBS_TRIANGLE_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
    )


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
