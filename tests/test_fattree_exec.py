"""Hierarchical fat-tree lowering: planner, conformance, and calibration.

Planning/trace/ranking tests run on duck-typed meshes (no jax execution);
the subprocess job forces 16 host devices and asserts the executed
program's collectives equal the schedule trace and the analytic per-level
words, that outputs match jnp.matmul, and that an injected wrong-exchange
mutation is caught at the interceptor.
"""
import dataclasses
import math
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.dist.api import estimate
from repro.obs.calibrate import _assemble_links
from repro.obs.profile import LinkParams, MachineProfile
from repro.plan import build_plan, mesh_candidates, rank_mesh_strategies
from repro.verify import ConformanceError, check, trace_plan, tree_level_words
from repro.verify.conformance import _check_structure, _xor_mask


def fake_mesh(sizes, names):
    total = math.prod(sizes)
    return SimpleNamespace(
        axis_names=tuple(names),
        shape=dict(zip(names, sizes)),
        size=total,
        devices=np.array([SimpleNamespace(id=i, platform="cpu")
                          for i in range(total)]),
    )


# ---------------------------------------------------------------------------
# planner: hierarchical axis roles, grid, padding, candidacy
# ---------------------------------------------------------------------------


def test_fattree_plan_reifies_hierarchy():
    mesh = fake_mesh((4, 2, 2), ("tree", "x", "y"))
    plan = build_plan(24, 24, 24, mesh=mesh, strategy="fattree",
                      use_cache=False)
    assert plan.grid == (4, 2, 2)
    assert plan.axes == ("tree", "x", "y")
    assert plan.axis_roles == (("tree", "tree"), ("x", "row"), ("y", "col"))
    # A is (row, tree x col)-sharded; k must pad to s*qx*qy on both operands
    assert plan.pad_a == (2, 16) and plan.pad_b == (16, 8)
    assert plan.replication == 1 and not plan.overlap
    assert plan.cost.strategy == "fattree"


def test_fattree_candidacy_needs_power_of_two_tree_axis():
    good = mesh_candidates(fake_mesh((2, 2, 2), ("tree", "x", "y")))
    assert "fattree" in good and "pod25d" in good
    bad = mesh_candidates(fake_mesh((3, 2, 2), ("tree", "x", "y")))
    assert "fattree" not in bad and "pod25d" in bad
    flat = mesh_candidates(fake_mesh((2, 2), ("x", "y")))
    assert "fattree" not in flat


def test_fattree_forced_on_bad_tree_axis_raises():
    mesh = fake_mesh((3, 2, 2), ("tree", "x", "y"))
    with pytest.raises(ValueError, match="power-of-two tree axis"):
        build_plan(24, 24, 24, mesh=mesh, strategy="fattree",
                   use_cache=False)
    with pytest.raises(ValueError, match=">= 3 axes"):
        build_plan(24, 24, 24, mesh=fake_mesh((2, 2), ("x", "y")),
                   strategy="fattree", use_cache=False)


def test_other_strategies_carry_axis_roles_too():
    mesh3 = fake_mesh((2, 2, 2), ("pod", "x", "y"))
    assert build_plan(24, 24, 24, mesh=mesh3, strategy="pod25d",
                      use_cache=False).axis_roles == \
        (("pod", "pod"), ("x", "row"), ("y", "col"))
    ring = build_plan(24, 24, 24, mesh=fake_mesh((4,), ("t",)),
                      strategy="ring_ag", use_cache=False)
    assert ring.axis_roles == (("t", "ring"),)


# ---------------------------------------------------------------------------
# conformance: structure predicate + the per-level triangle (static legs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 2, 2), (4, 2, 2), (8, 2, 2),
                                   (4, 1, 1), (2, 2, 4)])
def test_fattree_static_conformance(shape):
    """Structure + cost + per-level triangle on the virtual topology,
    including multi-level trees (s = 4, 8) and degenerate pods."""
    mesh = fake_mesh(shape, ("tree", "x", "y"))
    plan = build_plan(24, 24, 24, mesh=mesh, strategy="fattree",
                      use_cache=False)
    rep = check(plan)
    assert rep.strategy == "fattree" and rep.words_per_node > 0


def test_tree_level_words_closed_form():
    """Level l of an s-pod tree carries (s / 2^(l-1) - 1) * m * k words --
    the Gray-mask step count -- with exactly m*k across the root."""
    mesh = fake_mesh((8, 2, 2), ("tree", "x", "y"))
    plan = build_plan(16, 16, 256, mesh=mesh, strategy="fattree",
                      use_cache=False)
    levels = tree_level_words(trace_plan(plan))
    mk = 16 * 256
    assert levels == {1: 7 * mk, 2: 3 * mk, 3: 1 * mk}
    est = estimate("fattree", 16, 16, 256, 32, dtype_bytes=1,
                   grid=(8, 2, 2), axes=("tree", "x", "y"))
    assert est.tree_level_words == (7.0 * mk, 3.0 * mk, 1.0 * mk)


def test_xor_mask_predicate():
    from repro.core.fattree import tree_exchange_perm
    from repro.verify.trace import canonical_perm

    for s in (2, 4, 8):
        for t in range(s - 1):
            perm = canonical_perm(tree_exchange_perm(s, t))
            assert _xor_mask(perm, s) == t ^ (t + 1)
    # a ring translation is not an XOR involution (for s > 2)
    ring = canonical_perm([(d, (d + 1) % 4) for d in range(4)])
    assert _xor_mask(ring, 4) is None
    assert _xor_mask((), 4) is None


def test_structure_rejects_non_involution_exchange():
    """A movement perm that is a valid bijection but not an XOR-mask
    involution (a Gray-walk break) must fail the structure leg."""
    mesh = fake_mesh((4, 2, 2), ("tree", "x", "y"))
    plan = build_plan(24, 24, 24, mesh=mesh, strategy="fattree",
                      use_cache=False)
    trace = trace_plan(plan)
    ring = tuple((d, (d + 1) % 4) for d in range(4))
    recs = list(trace.records)
    idx = next(i for i, r in enumerate(recs) if r.phase == "movement")
    recs[idx] = dataclasses.replace(recs[idx], perm=ring)
    bad = dataclasses.replace(trace, records=tuple(recs))
    with pytest.raises(ConformanceError, match="XOR-mask involution"):
        _check_structure(plan, bad)


# ---------------------------------------------------------------------------
# calibration: DCN link class + the hierarchical ranking flip
# ---------------------------------------------------------------------------


def test_assemble_links_splits_dcn_from_ici():
    def samples(axis, alpha, bw):
        sizes = [1 << 14, 1 << 17, 1 << 20]
        return (axis, sizes, [alpha + s / bw for s in sizes])

    links = dict(_assemble_links(
        [samples("tree", 1e-3, 1e8), samples("x", 1e-6, 1e11),
         samples("y", 1e-6, 1e11)],
        tree_axes=("tree",)))
    assert set(links) == {"ici", "dcn", "axis:tree", "axis:x", "axis:y"}
    # the slow inter-pod link must not contaminate the pooled ICI fit
    assert links["ici"].alpha_s < 1e-4 < links["dcn"].alpha_s
    assert links["axis:tree"].alpha_s == pytest.approx(1e-3, rel=1e-3)
    # all-tree meshes still produce a usable pooled "ici" (= the dcn fit)
    only_tree = dict(_assemble_links([samples("tree", 1e-3, 1e8)],
                                     tree_axes=("tree",)))
    assert only_tree["ici"] == only_tree["dcn"]
    # no tree axes: identical to the historical pooled behavior
    flat = dict(_assemble_links([samples("x", 1e-6, 1e11)]))
    assert set(flat) == {"ici", "axis:x"}


def test_slow_tree_profile_flips_ranking_to_fattree():
    """The acceptance-criteria regression pin: with a latency-skewed tree
    axis (DCN-ish: 1 s alpha, 1 GB/s) and free intra-pod links, the
    calibrated ranking must prefer the hierarchical plan -- it crosses the
    tree axis once per super-step ((s-1) messages of A shards) while the
    flat strategies either reduce C over it or flatten it into their ring.
    The analytic (uncalibrated) ranking must NOT prefer it, or the test
    would pass vacuously."""
    mesh = fake_mesh((2, 2, 2), ("tree", "x", "y"))
    fast = LinkParams(alpha_s=0.0, bw_bytes_per_s=1e12)
    slow = LinkParams(alpha_s=1.0, bw_bytes_per_s=1e9)
    skewed = MachineProfile(
        platform="cpu", peak_flops=1e18,
        links=(("ici", slow), ("dcn", slow), ("axis:tree", slow),
               ("axis:x", fast), ("axis:y", fast)))
    m, n, k = 64, 32, 512
    assert rank_mesh_strategies(m, n, k, mesh)[0].strategy != "fattree"
    ranked = rank_mesh_strategies(m, n, k, mesh, profile=skewed)
    assert ranked[0].strategy == "fattree"
    # and the win is structural, not a tie: one tree round vs >= 2
    runner_up = skewed.seconds(ranked[1])
    assert skewed.seconds(ranked[0]) < 0.75 * runner_up


# ---------------------------------------------------------------------------
# executed program: real devices, interceptor == trace == analytics
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, numpy as np

from repro.dist import fattree_matmul
from repro.plan import build_plan, execute_plan
from repro.verify import (ConformanceError, check, compare_records,
                          measure_plan, trace_plan)

devs = np.array(jax.devices())
rng = np.random.default_rng(0)

# numeric + measured-conformance cells: square, ragged, batched, bf16
mesh8 = jax.make_mesh((2, 2, 2), ("tree", "x", "y"), devices=devs[:8])
for kwargs in ({"m": 24, "n": 24, "k": 24},
               {"m": 13, "n": 7, "k": 11},
               {"m": 5, "n": 8, "k": 12, "batch": (3,)},
               {"m": 16, "n": 16, "k": 16, "a_dtype": jnp.bfloat16,
                "b_dtype": jnp.bfloat16}):
    m, n, k = kwargs.pop("m"), kwargs.pop("n"), kwargs.pop("k")
    batch = kwargs.get("batch", ())
    dt = kwargs.get("a_dtype", jnp.float32)
    plan = build_plan(m, n, k, mesh=mesh8, strategy="fattree", **kwargs)
    a = jnp.asarray(rng.normal(size=batch + (m, k)), dt)
    b = jnp.asarray(rng.normal(size=(k, n)), dt)
    out = execute_plan(plan, a, b)
    ref = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    assert jnp.allclose(out.astype(jnp.float32), ref,
                        atol=2e-2, rtol=2e-2), (m, n, k)
    check(plan, measure=True)

# multi-level tree: 4 pods x (2 x 2), measured
mesh16 = jax.make_mesh((4, 2, 2), ("tree", "x", "y"), devices=devs[:16])
plan16 = build_plan(24, 24, 24, mesh=mesh16, strategy="fattree",
                    use_cache=False)
check(plan16, measure=True)

# facade
a = jnp.ones((16, 32)); b = jnp.ones((32, 8))
assert jnp.allclose(fattree_matmul(a, b, mesh=mesh8), a @ b)

# executed wrong-exchange mutation: break the Gray walk in the lowering
# only (the trace keeps the true program) -- the interceptor must diverge
import repro.dist.fattree as df
orig = df.tree_exchange_perm
df.tree_exchange_perm = lambda s, t: tuple((d, (d + 1) % s) for d in range(s))
try:
    cap = measure_plan(plan16)
finally:
    df.tree_exchange_perm = orig
try:
    compare_records(trace_plan(plan16).records, cap.records)
    raise SystemExit("executed exchange mutation not caught")
except ConformanceError:
    pass

print("FATTREE_EXEC_OK")
"""


@pytest.mark.timeout(600)
def test_fattree_execution_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_root(), "src")
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=590,
    )
    assert "FATTREE_EXEC_OK" in res.stdout, (
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
    )


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
