"""End-to-end training driver.

    PYTHONPATH=src python examples/train_lm.py --preset demo
        ~2M-param llama-family model, 200 steps on CPU (< ~2 min),
        shows loss dropping on the synthetic affine-chain data, writes
        checkpoints, and exercises a mid-run injected failure + restore.

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
        ~100M-param model, the configuration a real (TPU) run would use;
        on CPU this is hours -- the demo preset is the CI path.

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b --smoke
        any assigned architecture's smoke config through the same driver.
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, batch_iterator
from repro.models.config import ModelConfig
from repro.models.registry import build_model
from repro.runtime.train import Trainer, TrainConfig

DEMO = ModelConfig(
    name="demo-2m", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=2048, head_dim=32,
    attn_chunk=128, tie_embeddings=True,
)

M100 = ModelConfig(
    name="repro-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768, head_dim=64,
    tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["demo", "100m"], default="demo")
    ap.add_argument("--arch", default=None, help="assigned arch id instead")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--inject-failure", type=int, default=None)
    args = ap.parse_args()

    if args.arch:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        cfg = DEMO if args.preset == "demo" else M100
    steps = args.steps or (200 if args.preset == "demo" else 300)

    model = build_model(cfg)
    n = cfg.param_count()
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={steps}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=0)
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
    tc = TrainConfig(
        steps=steps, lr=args.lr, warmup=max(steps // 20, 5),
        ckpt_dir=ckpt_dir, ckpt_every=max(steps // 4, 10),
        log_every=max(steps // 20, 5),
        fail_at_step=args.inject_failure,
    )
    out = Trainer(model, tc).fit(jax.random.PRNGKey(0), batch_iterator(dc))
    hist = out["history"]
    print(f"\nfirst logged loss {hist[0]['loss']:.4f} -> last "
          f"{hist[-1]['loss']:.4f}  (restarts: {out['restarts']})")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
