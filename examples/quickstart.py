"""Quickstart: the paper's procedure end-to-end on one page.

1. SOLVE the commutative diagram for the 2D torus -> Cannon falls out.
2. COST the solutions (paper Sec. 2.4) and check the lower bound.
3. EXECUTE the derived schedule as a shard_map program (here: the
   algebraic simulator; see examples/distributed_matmul.py for devices).
4. The same algebra at the VMEM level: the Z-order Pallas matmul.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cannon_schedule, is_cannon_like, solve_torus
from repro.core.cost import (bandwidth_lower_bound, torus_schedule_cost)
from repro.core.hexarray import HexSchedule


def main():
    q = 5
    print(f"=== Solving the q={q} torus commutative diagram ===")
    sols = solve_torus(q)
    print(f"{len(sols)} valid equivariant schedules; min hop cost "
          f"{sols[0].hop_cost} (paper: 2 = two one-hop movers, one stationary)")
    best = sols[0]
    print(f"best movements: {dict(best.movements)}  cannon-like: "
          f"{is_cannon_like(best)}")

    cs = cannon_schedule(q)
    print(f"\nCannon's own matrix found: "
          f"{any(s.schedule.M == cs.M for s in sols)}")
    pl = cs.placement('A')
    print("derived initial placement of A (row i=1):",
          [tuple(int(v) for v in pl[1, s]) for s in range(q)],
          " <- the classic skew P_{i, j-i}")

    n, p = 4096, q * q
    rep = torus_schedule_cost(cs, n)
    lb = bandwidth_lower_bound(n, p, n * n / p)  # Cannon's one-block regime
    print(f"\nblocked Cannon comm, n={n}, p={p}: "
          f"{rep.words_per_node:.3e} words/node "
          f"(lower bound {lb:.3e}; factor {rep.words_per_node/max(lb, 1e-9):.1f}x)")

    print("\n=== Executing the schedule (algebraic simulator) ===")
    A = np.random.rand(q, q)
    B = np.random.rand(q, q)
    C = np.zeros((q, q))
    for i in range(q):
        for j in range(q):
            for k in range(q):
                x, y, t = cs.f(i, j, k)
                C[k, i] += A[i, j] * B[j, k]
    print("C == A@B:", np.allclose(C, (A @ B).T))

    print("\n=== Same algebra, hex VLSI array (paper Sec. D.2) ===")
    hs = HexSchedule(q=4)
    print("systolic properties:", hs.systolic_properties())

    print("\n=== Same algebra, VMEM level: Z-order Pallas matmul ===")
    import jax
    import jax.numpy as jnp
    from repro.kernels.matmul import matmul, matmul_ref
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    out = matmul(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
    print("kernel max err vs oracle:",
          float(jnp.max(jnp.abs(out - matmul_ref(a, b)))))


if __name__ == "__main__":
    main()
