"""Batched serving example: KV-cache decode over a request batch.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --arch zamba2-2.7b

Serves the (smoke-sized) model with a batch of prompts through the same
decode_step the decode_32k / long_500k dry-run cells lower -- full KV cache
for GQA archs, rolling window for SWA, latent cache for MLA, recurrent
state for SSM/hybrid.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.runtime.serve import ServeConfig, batch_requests, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=rng.integers(3, 9)).tolist()
               for _ in range(args.batch)]
    batch, lens = batch_requests(prompts)
    print(f"arch={cfg.name}: serving {len(prompts)} requests, "
          f"prompt lens {lens.tolist()}")

    sc = ServeConfig(max_new_tokens=args.max_new, max_seq=128)
    t0 = time.perf_counter()
    out = generate(model, params, batch, sc)
    dt = time.perf_counter() - t0
    new_tokens = args.max_new * len(prompts)
    print(f"generated {new_tokens} tokens in {dt:.2f}s "
          f"({new_tokens/dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(out):
        print(f"req{i}: ...{row[-args.max_new:].tolist()}")


if __name__ == "__main__":
    main()
