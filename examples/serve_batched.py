"""Plan-routed batched serving example: bucketed warmup + mesh decode.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --no-mesh

Builds a ``repro.serve.Server`` over a 4-device (2x2) mesh: warmup
AOT-compiles the declared (batch, seq) buckets and fills the plan cache
with each bucket's solver-derived ``SchedulePlan``s; the request batch is
then routed to the nearest warm bucket (left-padded, offset-corrected)
and every decode matmul executes its planned schedule.  ``--no-mesh``
serves the local single-device baseline instead -- same buckets, same
tokens, no plan engine.
"""
import argparse
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402

from repro.configs import get_smoke_config                   # noqa: E402
from repro.models.registry import build_model                # noqa: E402
from repro.runtime.serve import ServeConfig                  # noqa: E402
from repro.serve import Server                               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--no-mesh", action="store_true",
                    help="serve the local baseline instead of plan-routed")
    ap.add_argument("--strategy", default=None,
                    help="pin one schedule family (cannon, summa, ...)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mesh = None
    if not args.no_mesh:
        devs = jax.devices()
        if len(devs) < 4:
            raise SystemExit(f"need 4 devices for the 2x2 mesh, have "
                             f"{len(devs)}; run with --no-mesh or set "
                             f"XLA_FLAGS=--xla_force_host_platform_device_count=4")
        mesh = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])

    sc = ServeConfig(max_new_tokens=args.max_new, max_seq=128)
    server = Server(model, params, sc, mesh=mesh, strategy=args.strategy,
                    buckets=[(4, 16), (4, 32)])
    for label, w in server.warmup().items():
        print(f"warmup {label}: {w['plans']} plans in {w['warm_s']:.2f}s")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=rng.integers(3, 9)).tolist()
               for _ in range(args.batch)]
    print(f"arch={cfg.name} {'local' if mesh is None else 'plan-routed 2x2'}: "
          f"serving {len(prompts)} requests, "
          f"lens {[len(p) for p in prompts]}")

    res = server.generate(prompts)
    q = res.latency_quantiles_ms()
    print(f"bucket={res.bucket}: {res.generated_tokens} tokens in "
          f"{res.wall_s:.2f}s ({res.tokens_per_s:.1f} tok/s), "
          f"ttft {res.ttft_s * 1e3:.1f}ms, p50 {q['p50_ms']:.2f}ms")
    for i, toks in enumerate(res.new_tokens):
        print(f"req{i}: ...{toks}")

    rep = server.cache_report()
    sw = rep.get("serve_window") or {}
    print(f"plan cache: {rep['info']['currsize']} plans, serve-window "
          f"hit rate {sw.get('hit_rate')}")


if __name__ == "__main__":
    main()
