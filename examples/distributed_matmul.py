import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
"""Distributed matmul strategies on 16 fake devices (runs anywhere).

    python examples/distributed_matmul.py        # PYTHONPATH=src

Executes the solver-derived Cannon schedule, SUMMA, the ring collective
matmuls and the 2.5D pod split on a fake 16-device mesh, verifies each
against the XLA reference, and prints the per-strategy collective bytes
parsed from the compiled HLO next to the paper's analytic cost model.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.cost import torus_schedule_cost
from repro.core.schedule import cannon_schedule
from repro.dist import (cannon_matmul, pod25d_matmul, ring_ag_matmul,
                        ring_rs_matmul, summa_matmul)
from repro.roofline.hlo_stats import analyze


def main():
    devs = np.array(jax.devices())
    q, n = 4, 512
    mesh = jax.make_mesh((q, q), ("x", "y"), devices=devs[: q * q])
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
    ref = (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(jnp.bfloat16)

    print(f"=== {n}x{n} matmul on a {q}x{q} fake torus ===")
    # summa staged keeps the classic all-gather signature; summa+overlap
    # decomposes each gather into the one-hop ppermute chain it can hide
    # behind the local multiplies (same words either way)
    for name, fn in (
            ("cannon", cannon_matmul),
            ("summa", functools.partial(summa_matmul, overlap=False)),
            ("summa+ov", functools.partial(summa_matmul, overlap=True)),
    ):
        f = jax.jit(functools.partial(fn, mesh=mesh, axis_x="x", axis_y="y"))
        comp = f.lower(a, b).compile()
        out = f(a, b)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        stats = analyze(comp.as_text())
        print(f"{name:8s} err={err:.3f}  coll_bytes/dev={stats.coll_bytes:.3e} "
              f"by_kind={ {k: int(v) for k, v in stats.coll.items() if v} }")

    rep = torus_schedule_cost(cannon_schedule(q), n)
    print(f"paper cost model: cannon words/node = {rep.words_per_node:.3e} "
          f"(x2 bytes bf16 = {2*rep.words_per_node:.3e} B)")

    print("\n=== 2.5D: contraction split over a pod axis (c=2) ===")
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "x", "y"), devices=devs[:8])
    f25 = jax.jit(functools.partial(pod25d_matmul, mesh=mesh3, pod_axis="pod"))
    out = f25(a, b)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    stats = analyze(f25.lower(a, b).compile().as_text())
    print(f"pod25d   err={err:.3f}  coll_bytes/dev={stats.coll_bytes:.3e}")

    print("\n=== ring collective matmuls (1-D torus solutions) ===")
    mesh_r = jax.make_mesh((8,), ("t",), devices=devs[:8])
    s, d, fdim = 512, 256, 256
    x = jax.random.normal(jax.random.PRNGKey(2), (s, d), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(3), (d, fdim), jnp.bfloat16)
    ag = jax.jit(jax.shard_map(
        lambda xl, wl: ring_ag_matmul(xl, wl, "t"), mesh=mesh_r,
        in_specs=(P("t", None), P(None, "t")), out_specs=P(None, "t")))
    out = ag(x, w)
    ref2 = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref2)))
    stats = analyze(ag.lower(x, w).compile().as_text())
    print(f"ring_ag  err={err:.3f}  coll_bytes/dev={stats.coll_bytes:.3e} "
          f"(collective-permute chain, overlappable)")


if __name__ == "__main__":
    main()
