"""Time and communication costs for schedules (Sec. 2.4) + lower bounds.

Costs are *words moved* and *time steps*, exactly as the paper assigns them:
a schedule's communication cost is the per-step hop count of each variable
set's movement homomorphism mu, times the number of variables, times the
number of steps; time cost is the flattened |T| (rho_T stretching).

Also provides the classical lower bounds the paper cites ([20] Irony-Toledo-
Tiskin, [11] Christ et al.):  per-node bandwidth  Omega(n^3 / (p sqrt(M))),
and the memory-independent  Omega(n^2 / p^{2/3}).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .schedule import TorusSchedule, Torus25DSchedule, torus_hops


@dataclasses.dataclass(frozen=True)
class CommReport:
    words_total: float          # words crossing links, summed over steps
    words_per_node: float
    steps: int
    per_variable: Dict[str, float]


def torus_schedule_cost(sched: TorusSchedule, n: int) -> CommReport:
    """Blocked execution of an n x n x n multiply on the q x q torus under
    ``sched`` (paper Sec. 4.1 blocked variant): each node holds one
    (n/q) x (n/q) block per variable; each time step moves each variable set
    by mu (hop count x q^2 blocks x block words)."""
    q = sched.q
    block_words = (n / q) ** 2
    steps = sched.t
    per_var = {}
    total = 0.0
    for v in ("A", "B", "C"):
        mv = sched.movement(v)
        hops = torus_hops(mv, q) if mv is not None else float("inf")
        words = hops * block_words * q * q * max(steps - 1, 0)
        per_var[v] = words
        total += words
    return CommReport(
        words_total=total,
        words_per_node=total / (q * q),
        steps=steps,
        per_variable=per_var,
    )


def cannon_comm_total(n: int, p: int) -> float:
    """Paper's closed form: blocked Cannon on sqrt(p) x sqrt(p) nodes moves
    ~ 2 * sqrt(p) * p * (n^2/p) = 2 n^2 sqrt(p) words (A and B each one hop
    per step; the paper quotes 3 n^2 sqrt(p) counting all three sets)."""
    return 2.0 * n * n * math.sqrt(p)


def schedule_25d_cost(sched: Torus25DSchedule, n: int) -> CommReport:
    q, c, t = sched.q, sched.c, sched.t
    p = q * q * c
    block_words = (n / q) ** 2
    shift = 2 * block_words * q * q * c * max(t - 1, 0)  # A,B one-hop in-layer
    repl = 2 * block_words * q * q * (c - 1)  # broadcast copies over z
    red = block_words * q * q * (c - 1)  # reduce C over z
    total = shift + repl + red
    return CommReport(
        words_total=total,
        words_per_node=total / p,
        steps=t,
        per_variable={"shift": shift, "replicate": repl, "reduce": red},
    )


def perm_link_words(perm, q: int, block_words: float) -> float:
    """Torus link-words of one executed ppermute: each (src, dst) pair's
    block transits ``torus_hops`` links under minimal routing on the q x q
    torus.  For a translation perm this is hops(mu) * q^2 * block_words --
    the per-step term of ``torus_schedule_cost`` -- but the formula accepts
    arbitrary perms so conformance can price a *wrong* program too."""
    total = 0.0
    for src, dst in perm:
        sx, sy = divmod(int(src), q)
        dx, dy = divmod(int(dst), q)
        total += torus_hops((dx - sx, dy - sy), q) * block_words
    return total


# ---------------------------------------------------------------------------
# Lower bounds
# ---------------------------------------------------------------------------


def bandwidth_lower_bound(n: int, p: int, M: float) -> float:
    """Irony-Toledo-Tiskin [20]: words per node >= n^3/(2*sqrt(2)*p*sqrt(M)) - M."""
    return max(n**3 / (2 * math.sqrt(2) * p * math.sqrt(M)) - M, 0.0)


def memory_independent_lower_bound(n: int, p: int) -> float:
    """[11]: words per node >= c * n^2 / p^(2/3)."""
    return n * n / (p ** (2.0 / 3.0))


def optimal_replication(n: int, p: int, M: float) -> int:
    """The 2.5D sweet spot c = p*M/(3n^2) clamped to [1, p^(1/3)]."""
    c = p * M / (3.0 * n * n)
    return max(1, min(int(c), int(round(p ** (1.0 / 3.0)))))


# ---------------------------------------------------------------------------
# TPU hardware constants (v5e targets used across roofline + cost model)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (one direction)
VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB v5e vector memory
MXU_DIM = 128             # systolic array tile edge


def calibrated_total_s(flops: float, comm_bytes: float, msgs: float, *,
                       alpha_s: float, bw_bytes_per_s: float,
                       peak_flops: float, overlapped: bool,
                       comm_terms=None, compute_s=None) -> float:
    """Calibrated seconds for one strategy cell: the analytic word/message
    counts priced with *measured* machine parameters (a fitted
    ``repro.obs.profile.MachineProfile``) instead of the datasheet
    constants above.

    ``msgs`` is the strategy's collective-round count (the latency term the
    α–β model adds over the pure-bandwidth analytic model): compute is
    ``flops / peak_flops``, communication ``msgs * α + bytes / bw``, and
    the two combine under the strategy's own overlap rule -- exactly the
    ``Estimate.total_s`` shape, with calibrated coefficients.  With α = 0
    and the datasheet bw/flops this reproduces the analytic ranking
    (``repro.obs.default_profile`` pins that identity).

    ``comm_terms``, when given, replaces the pooled α–β pair with per-axis
    pricing: an iterable of ``(alpha_s, bw_bytes_per_s, bytes, msgs)``
    tuples (one per mesh axis the strategy moves words over), summed into
    the communication time.  The pooled ``alpha_s``/``bw_bytes_per_s``/
    ``comm_bytes``/``msgs`` arguments are ignored in that case.

    ``compute_s``, when given, replaces the peak-FLOPs roofline with a
    *measured* compute time -- the ``repro.tune`` path: tuned kernel
    seconds on the compute side of the same max/sum combination the
    calibrated comm terms sit on.
    """
    if compute_s is None:
        compute_s = flops / max(peak_flops, 1e-9)
    if comm_terms is not None:
        comm_s = sum(ms * a + b / max(bw, 1e-9)
                     for a, bw, b, ms in comm_terms)
    else:
        comm_s = msgs * alpha_s + comm_bytes / max(bw_bytes_per_s, 1e-9)
    return max(compute_s, comm_s) if overlapped else compute_s + comm_s


def matmul_time_model(m: int, n: int, k: int, dtype_bytes: int = 2) -> Dict[str, float]:
    """Single-chip roofline terms for an (m,k)x(k,n) matmul."""
    flops = 2.0 * m * n * k
    bytes_moved = dtype_bytes * (m * k + k * n + m * n)
    return {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_moved / HBM_BW,
        "arithmetic_intensity": flops / bytes_moved,
    }
