"""Finite groups, actions and the specific group families the paper uses.

The paper models
  * algorithm symmetry as the action of ``S_l x S_m x S_n`` on the instruction
    set ``X = {(i,j,k)}`` of classical matmul (Sec. 2.1),
  * machines as the action of a network group ``N`` times a time-increment
    group ``Delta`` on ``P x T`` (Sec. 2.2),
  * and builds schedules from homomorphisms between subgroups of these.

We implement exactly the group families needed to *compute* with the paper's
constructions: cyclic groups Z/nZ, direct products, permutations (with the
paper's primitive/imprimitive distinction from Lemmas 3-5), cyclic-shift
subgroups ``Sigma_q``, and iterated wreath products ``S2^{wr k}`` modelling
fat-trees.  Everything is small, exact integer math -- this layer is the
"solve algebraic equations" part of the paper, not a performance path.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, Sequence, Tuple


# ---------------------------------------------------------------------------
# Cyclic groups and products of them (abelian machine/network groups)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CyclicGroup:
    """Z/nZ with elements ``0..n-1`` under addition mod n."""

    n: int

    @property
    def identity(self) -> int:
        return 0

    def add(self, a: int, b: int) -> int:
        return (a + b) % self.n

    def neg(self, a: int) -> int:
        return (-a) % self.n

    def mul(self, a: int, k: int) -> int:
        """k-fold repeated addition (integer scalar times element)."""
        return (a * k) % self.n

    def elements(self) -> range:
        return range(self.n)

    def order_of(self, a: int) -> int:
        return self.n // math.gcd(self.n, a % self.n) if a % self.n else 1

    def __len__(self) -> int:
        return self.n


@dataclasses.dataclass(frozen=True)
class ProductGroup:
    """Direct product of cyclic groups; elements are int tuples.

    Models e.g. the 2D-torus network group (Z/qZ)^2, the 3D torus
    (Z/qZ)^2 x Z/cZ of the 2.5D algorithm, and N x Delta.
    """

    moduli: Tuple[int, ...]

    @property
    def identity(self) -> Tuple[int, ...]:
        return tuple(0 for _ in self.moduli)

    def add(self, a: Sequence[int], b: Sequence[int]) -> Tuple[int, ...]:
        return tuple((x + y) % n for x, y, n in zip(a, b, self.moduli))

    def neg(self, a: Sequence[int]) -> Tuple[int, ...]:
        return tuple((-x) % n for x, n in zip(a, self.moduli))

    def mul(self, a: Sequence[int], k: int) -> Tuple[int, ...]:
        return tuple((x * k) % n for x, n in zip(a, self.moduli))

    def elements(self) -> Iterable[Tuple[int, ...]]:
        return itertools.product(*(range(n) for n in self.moduli))

    def order_of(self, a: Sequence[int]) -> int:
        orders = [
            (n // math.gcd(n, x % n)) if x % n else 1
            for x, n in zip(a, self.moduli)
        ]
        return math.lcm(*orders) if orders else 1

    def __len__(self) -> int:
        return math.prod(self.moduli)


# ---------------------------------------------------------------------------
# Permutations (subgroups of S_q; algorithm-symmetry side)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Permutation:
    """A permutation of [q] as the tuple ``image`` with image[i] = sigma(i)."""

    image: Tuple[int, ...]

    @staticmethod
    def identity(q: int) -> "Permutation":
        return Permutation(tuple(range(q)))

    @staticmethod
    def cyclic_shift(q: int, step: int = 1) -> "Permutation":
        """The one-step shift sigma_-> : i -> i + step (mod q) of the paper."""
        return Permutation(tuple((i + step) % q for i in range(q)))

    @staticmethod
    def from_cycles(q: int, cycles: Sequence[Sequence[int]]) -> "Permutation":
        img = list(range(q))
        for cyc in cycles:
            for a, b in zip(cyc, cyc[1:] + type(cyc)([cyc[0]])):
                img[a] = b
        return Permutation(tuple(img))

    @property
    def q(self) -> int:
        return len(self.image)

    def __call__(self, i: int) -> int:
        return self.image[i]

    def compose(self, other: "Permutation") -> "Permutation":
        """(self o other)(i) = self(other(i))."""
        return Permutation(tuple(self.image[other.image[i]] for i in range(self.q)))

    def inverse(self) -> "Permutation":
        inv = [0] * self.q
        for i, v in enumerate(self.image):
            inv[v] = i
        return Permutation(tuple(inv))

    def power(self, k: int) -> "Permutation":
        if k < 0:
            return self.inverse().power(-k)
        out = Permutation.identity(self.q)
        base = self
        while k:
            if k & 1:
                out = out.compose(base)
            base = base.compose(base)
            k >>= 1
        return out

    def is_identity(self) -> bool:
        return all(v == i for i, v in enumerate(self.image))

    def cycle_type(self) -> Tuple[int, ...]:
        seen = [False] * self.q
        lens = []
        for i in range(self.q):
            if seen[i]:
                continue
            n, j = 0, i
            while not seen[j]:
                seen[j] = True
                j = self.image[j]
                n += 1
            lens.append(n)
        return tuple(sorted(lens, reverse=True))

    def order(self) -> int:
        return math.lcm(*self.cycle_type())

    def is_primitive(self) -> bool:
        """Paper's Sec. 4 notion: a permutation is *imprimitive* when its cycle
        decomposition splits [q] into non-trivial parts; primitive otherwise
        (single q-cycle). Used by Lemmas 3-5."""
        return self.cycle_type() == (self.q,)


def sigma_subgroup(q: int) -> list:
    """The transitive cyclic subgroup Sigma_q <= S_q generated by sigma_->.

    Sigma_q ~ Z/qZ; the paper builds all the torus schedules from it."""
    s = Permutation.cyclic_shift(q)
    out, cur = [], Permutation.identity(q)
    for _ in range(q):
        out.append(cur)
        cur = cur.compose(s)
    return out


# ---------------------------------------------------------------------------
# Iterated wreath product S2^{wr k}  (fat-tree network group, Sec. 2.5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WreathTreeElement:
    """An element of S2^{wr k} acting on 2^k leaves.

    Represented by one swap-bit per internal node of the complete binary tree
    (levels 1..k, level k = root).  ``swaps[l]`` is a tuple of 2^(k-l) bits for
    level l: bit b says "swap the two children of the b-th node at level l".
    The action on a leaf applies level-k (root) first, then descends; this is
    exactly the paper's "at each internal node ... choose to swap the left and
    right subtree" description.
    """

    k: int
    swaps: Tuple[Tuple[int, ...], ...]  # swaps[l-1] has 2^(k-l) entries

    @staticmethod
    def identity(k: int) -> "WreathTreeElement":
        return WreathTreeElement(
            k, tuple(tuple(0 for _ in range(2 ** (k - l))) for l in range(1, k + 1))
        )

    @staticmethod
    def level_swap(k: int, level: int, node: int) -> "WreathTreeElement":
        """Generator: swap the children of ``node`` at ``level`` (1-based)."""
        sw = [list((0,) * (2 ** (k - l))) for l in range(1, k + 1)]
        sw[level - 1][node] = 1
        return WreathTreeElement(k, tuple(tuple(row) for row in sw))

    def apply(self, leaf: int) -> int:
        """Image of a leaf index in [2^k] under this element."""
        # Walk from root down; at level l the current node index is the top
        # (k-l) bits of the (partially permuted) leaf index.
        x = leaf
        for l in range(self.k, 0, -1):
            node = x >> l  # index of the level-l node containing x
            if self.swaps[l - 1][node]:
                x ^= 1 << (l - 1)  # swap the two subtrees: flip bit l-1
        return x

    def compose(self, other: "WreathTreeElement") -> "WreathTreeElement":
        """self o other via action composition (exact, by tabulation)."""
        assert self.k == other.k
        n = 2 ** self.k
        table = [self.apply(other.apply(i)) for i in range(n)]
        return WreathTreeElement.from_table(self.k, tuple(table))

    @staticmethod
    def from_table(k: int, table: Tuple[int, ...]) -> "WreathTreeElement":
        """Reconstruct the swap-bit representation from a permutation table
        that is promised to lie in S2^{wr k}."""
        table = list(table)
        swaps = []
        # Peel from the root down: at level l, node b is swapped iff the
        # current table maps its left half into the right half.
        for l in range(k, 0, -1):
            row = []
            for b in range(2 ** (k - l)):
                base = b << l
                # Node b is swapped iff its left half [base, base+2^(l-1))
                # lands in the right half under the (residual) map.
                lo = table[base]
                row.append(1 if ((lo >> (l - 1)) & 1) != ((base >> (l - 1)) & 1) else 0)
            # normalize: row computed w.r.t. original positions; apply it
            # to the table so lower levels see the residual permutation.
            new_table = list(table)
            if any(row):
                for i in range(2 ** k):
                    node = i >> l
                    if row[node]:
                        new_table[i ^ (1 << (l - 1))] = table[i]
                table = new_table
            swaps.append(tuple(row))
        swaps.reverse()  # stored level-1-first
        return WreathTreeElement(k, tuple(swaps))

    def is_identity(self) -> bool:
        return all(all(b == 0 for b in row) for row in self.swaps)


def fat_tree_group_size(k: int) -> int:
    """|S2^{wr k}| = 2^(2^k - 1) (paper Sec. 2.5 notes 2^(n-1) elements)."""
    return 2 ** (2 ** k - 1)


# ---------------------------------------------------------------------------
# Hexagonal VLSI lattice group (Sec. D.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HexLattice:
    """The free abelian group <g1,g2,g3 | g1 = g2 g3> acting on the hex array.

    We coordinatize with basis (g2, g3) so g2=(1,0), g3=(0,1), g1=(1,1);
    elements are integer 2-vectors, the action is translation.  Each of the
    three generators corresponds to one of the three link directions of the
    hexagonal multiply-accumulate array of Kung [24].
    """

    g1: Tuple[int, int] = (1, 1)
    g2: Tuple[int, int] = (1, 0)
    g3: Tuple[int, int] = (0, 1)

    def translate(self, node: Tuple[int, int], vec: Tuple[int, int]) -> Tuple[int, int]:
        return (node[0] + vec[0], node[1] + vec[1])

    def combine(self, a2: int, a3: int) -> Tuple[int, int]:
        """a2*g2 + a3*g3."""
        return (a2 * self.g2[0] + a3 * self.g3[0], a2 * self.g2[1] + a3 * self.g3[1])

    @staticmethod
    def link_hops(vec: Tuple[int, int]) -> int:
        """Minimal number of single-link moves realizing translation ``vec``.

        Links are +-g1, +-g2, +-g3 with g1 = g2+g3; the hex-lattice word
        metric is |x|+|y| when x,y have opposite signs, max(|x|,|y|) when the
        same sign (diagonal g1 moves cover both)."""
        x, y = vec
        if (x >= 0) == (y >= 0):
            return max(abs(x), abs(y))
        return abs(x) + abs(y)
