"""Solving the commutative diagrams for schedules (Sec. 3 + Sec. 4.1).

The paper's procedure:
  1. pick a subgroup of the symmetry group (here Sigma_q^3, the cyclic-shift
     subgroup -- Lemma 4 says for prime q it is the only source of
     non-trivial homomorphisms to Z/qZ),
  2. enumerate homomorphisms rho to N x Delta by generator images,
  3. solve the commutative diagram (embedding + data-movement consistency),
  4. keep the minimum-cost solutions.

``solve_torus`` does exactly this for the q x q torus: it enumerates the
3 x 3 generator-image matrices with entries in a small window (one-hop
movement can only arise from +-1/0 images -- larger entries cost more hops,
monotonically, so the window is exact for finding *minimal* solutions),
filters by embedding + diagram solvability, and ranks by total hop cost.
Cannon and its unimodular variants fall out as the cost-2 family.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from .schedule import TorusSchedule, torus_hops


@dataclasses.dataclass(frozen=True)
class Solution:
    schedule: TorusSchedule
    hop_cost: int
    movements: Tuple[Tuple[str, Tuple[int, int]], ...]

    @property
    def stationary_vars(self) -> Tuple[str, ...]:
        return tuple(v for v, mv in self.movements if mv == (0, 0))


def solve_torus(
    q: int,
    window: Sequence[int] = (-1, 0, 1),
    max_solutions: Optional[int] = None,
    require_stationary: Optional[str] = None,
) -> List[Solution]:
    """Enumerate valid schedules for the q x q torus, sorted by hop cost.

    window: candidate values (mod q) for each entry of M.  (-1,0,1) suffices
    to find all one-hop-per-step schedules; widen to audit costlier ones.
    """
    sols: List[Solution] = []
    seen_M = set()
    for rows in itertools.product(itertools.product(window, repeat=3), repeat=3):
        M = tuple(tuple(int(v) % q for v in row) for row in rows)
        if M in seen_M:
            continue
        seen_M.add(M)
        sched = TorusSchedule(q=q, t=q, M=M)
        if not sched.is_embedding():
            continue
        moves = sched.movements()
        if moves is None:
            continue
        if require_stationary and moves[require_stationary] != (0, 0):
            continue
        cost = sum(torus_hops(mv, q) for mv in moves.values())
        # full validation (placement bijectivity) only for survivors
        if not sched.validate():
            continue
        sols.append(
            Solution(
                schedule=sched,
                hop_cost=cost,
                movements=tuple(sorted(moves.items())),
            )
        )
    sols.sort(key=lambda s: (s.hop_cost, s.schedule.M))
    if max_solutions is not None:
        sols = sols[:max_solutions]
    return sols


def minimal_hop_cost(q: int) -> int:
    """The minimum total per-step hop cost over valid schedules.

    The paper (Sec. 4.1): "the movement cost factor determined by mu can be 0
    for at most one of [A, B, C]" -- so the minimum is 2 (two variables each
    moving one hop, one stationary), which Cannon attains.
    """
    sols = solve_torus(q)
    return sols[0].hop_cost if sols else -1


def is_cannon_like(sol: Solution) -> bool:
    """Cost-2 with exactly one stationary variable and two one-hop movers."""
    hops = [torus_hops(mv, sol.schedule.q) for _, mv in sol.movements]
    return sorted(hops) == [0, 1, 1]


def at_most_one_stationary(q: int) -> bool:
    """Executable form of the paper's claim: no valid schedule keeps two of
    A, B, C stationary (their movement homomorphisms cannot both vanish)."""
    for rows in itertools.product(itertools.product((-1, 0, 1), repeat=3), repeat=3):
        sched = TorusSchedule(q=q, t=q, M=tuple(tuple(v % q for v in r) for r in rows))
        if not sched.is_embedding():
            continue
        moves = sched.movements()
        if moves is None:
            continue
        if sum(1 for mv in moves.values() if mv == (0, 0)) > 1:
            return False
    return True
