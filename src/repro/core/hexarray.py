"""Hexagonal VLSI systolic schedule (Sec. D.2, Kung [24]) + simulator.

The network group is the free abelian group <g1,g2,g3 | g1 = g2*g3> acting on
the infinite hex lattice; with basis (g2, g3) nodes are integer pairs.  The
homomorphism of Sec. D.2,

    rho(sigma_i) = ( g2, dt)      A-streams flow along +g2
    rho(sigma_j) = (-g1, dt)      B... (j advances the C anti-stream -g1)
    rho(sigma_k) = ( g3, dt)      ... along +g3

with Delta = Z/3qZ gives the systolic schedule f(i,j,k) =
(i*g2 - j*g1 + k*g3, i+j+k).  There is no user-programmable TPU analogue
(the MXU *is* a fixed-function systolic array), so this module is a faithful
algebraic simulator used by the Sec.-D.2 benchmark: it checks the systolic
properties (<=1 MAC per node per step; each variable moves one fixed link per
step -- Kung's "direction, speed and timing") and that the computed C matches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from .groups import HexLattice


@dataclasses.dataclass(frozen=True)
class HexSchedule:
    q: int
    lattice: HexLattice = HexLattice()

    def f(self, i: int, j: int, k: int) -> Tuple[Tuple[int, int], int]:
        """(node, time) for instruction (i,j,k): node = i*g2 - j*g1 + k*g3."""
        g1, g2, g3 = self.lattice.g1, self.lattice.g2, self.lattice.g3
        node = (
            i * g2[0] - j * g1[0] + k * g3[0],
            i * g2[1] - j * g1[1] + k * g3[1],
        )
        return node, i + j + k

    @property
    def num_steps(self) -> int:
        return 3 * self.q - 2

    def movement_vectors(self) -> Dict[str, Tuple[int, int]]:
        """Per-step translation of each variable stream (time-invariant mu).

        A_ij is used by instructions (i, j, k) for all k at times i+j+k:
        consecutive uses differ by +g3 per unit time -> A flows along g3.
        B_jk flows along g2; C_ki flows along -g1 (accumulates en route)."""
        g1, g2, g3 = self.lattice.g1, self.lattice.g2, self.lattice.g3
        return {"A": g3, "B": g2, "C": (-g1[0], -g1[1])}

    def systolic_properties(self) -> Dict[str, bool]:
        q = self.q
        occupancy: Dict[Tuple[Tuple[int, int], int], int] = {}
        ok_one_mac = True
        for i in range(q):
            for j in range(q):
                for k in range(q):
                    node, t = self.f(i, j, k)
                    keyt = (node, t)
                    occupancy[keyt] = occupancy.get(keyt, 0) + 1
                    if occupancy[keyt] > 1:
                        ok_one_mac = False
        times = [t for (_, t) in occupancy]
        span_ok = (max(times) - min(times) + 1) == self.num_steps
        mv = self.movement_vectors()
        one_hop = all(self.lattice.link_hops(v) == 1 for v in mv.values())
        return {"one_mac_per_node_step": ok_one_mac,
                "time_span_3q_minus_2": span_ok,
                "one_link_per_step": one_hop}

    def simulate(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        """Execute the schedule literally: every instruction (i,j,k) fires at
        f(i,j,k) and accumulates A[i,j]*B[j,k] into C[k,i] (paper layout
        C_ki += A_ij * B_jk); returns C as (AB) in C[k,i] = (A@B)[i,k]."""
        q = self.q
        assert A.shape == (q, q) and B.shape == (q, q)
        C = np.zeros((q, q), dtype=np.result_type(A, B))
        # Group instructions by time step to emulate the systolic wavefront.
        for t in range(0, 3 * q - 2):
            for i in range(q):
                for j in range(q):
                    k = t - i - j
                    if 0 <= k < q:
                        C[k, i] += A[i, j] * B[j, k]
        return C

    def reference(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        return (A @ B).T  # C[k,i] = (A@B)[i,k]
