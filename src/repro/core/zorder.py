"""Space-bounded / cache-oblivious schedules as Z-order traversals (Sec. 4.3).

The paper's parallel-memory-hierarchy schedule is equivariant with an
iterated-wreath-product homomorphism that lifts low-order index bits to small
time steps -- i.e. a Z-order (Morton) traversal of the (i, j, k) block index
space, executing the largest sub-multiplication that fits each cache level
contiguously.  On TPU the "cache" is VMEM: the Pallas matmul kernel in
``repro.kernels.matmul`` consumes these orders as its grid ``index_map``.

Also provides the analytic cache-miss/traffic model used by the
space-bounded benchmark: Z-order achieves the O(n^3 / sqrt(M)) transfer bound
at every level (cache-oblivious, Frigo et al. [16]); row-major does not.
"""
from __future__ import annotations

import math
from typing import Iterator, List, Tuple


def morton_decode3(code: int) -> Tuple[int, int, int]:
    """De-interleave bits code -> (i, j, k); bit 0 -> k, bit 1 -> j, bit 2 -> i."""
    i = j = k = 0
    bit = 0
    while code:
        k |= (code & 1) << bit
        j |= ((code >> 1) & 1) << bit
        i |= ((code >> 2) & 1) << bit
        code >>= 3
        bit += 1
    return i, j, k


def morton_encode3(i: int, j: int, k: int) -> int:
    out = 0
    bit = 0
    while i or j or k:
        out |= (k & 1) << (3 * bit)
        out |= (j & 1) << (3 * bit + 1)
        out |= (i & 1) << (3 * bit + 2)
        i >>= 1
        j >>= 1
        k >>= 1
        bit += 1
    return out


def enclosing_pow2(n: int) -> int:
    """Smallest power of two >= n (the enclosing-cube side for a grid dim).

    Exact for n < 2**47 (float log2 rounding is the limit); block-grid
    dims sit orders of magnitude below that -- a side**3 enumeration is
    infeasible long before -- and ``zorder_schedule`` asserts full grid
    coverage after filtering.
    """
    return 1 if n <= 1 else 2 ** math.ceil(math.log2(n))


def zorder_schedule(gi: int, gj: int, gk: int) -> List[Tuple[int, int, int]]:
    """Z-order traversal of a (gi, gj, gk) block grid (grid dims need not be
    powers of two: we enumerate the enclosing power-of-two cube and filter --
    order preserved, cost identical on the valid region)."""
    side = enclosing_pow2(max(gi, gj, gk))
    out = []
    for code in range(side ** 3):
        i, j, k = morton_decode3(code)
        if i < gi and j < gj and k < gk:
            out.append((i, j, k))
    assert len(out) == gi * gj * gk
    return out


def rowmajor_schedule(gi: int, gj: int, gk: int) -> List[Tuple[int, int, int]]:
    return [(i, j, k) for i in range(gi) for j in range(gj) for k in range(gk)]


def block_reuse_distance_traffic(
    order: List[Tuple[int, int, int]], cache_blocks: int
) -> int:
    """LRU-model traffic: number of (variable, block) fetches that miss an
    LRU cache holding ``cache_blocks`` blocks, where step (i,j,k) touches
    blocks A[i,k_? ] -- here A(i,j), B(j,k), C(i,k) in block units.

    This is the machine side of Sec. 4.3: the space-bounded schedule's
    traffic at a level of size M is O(#steps / sqrt(M)) block fetches."""
    from collections import OrderedDict

    lru: "OrderedDict[Tuple[str, int, int], None]" = OrderedDict()
    misses = 0
    for (i, j, k) in order:
        for key in (("A", i, j), ("B", j, k), ("C", i, k)):
            if key in lru:
                lru.move_to_end(key)
            else:
                misses += 1
                lru[key] = None
                if len(lru) > cache_blocks:
                    lru.popitem(last=False)
    return misses


def ideal_traffic(num_steps: int, cache_blocks: int) -> float:
    """O(steps / sqrt(M)) transfer bound (blocks) for matmul at cache size M."""
    return 3.0 * num_steps / math.sqrt(max(cache_blocks // 3, 1))


def zorder_grid_index_map(gi: int, gj: int, gk: int):
    """Return index_map(step) -> (i, j, k) for a 1-D Pallas grid of size
    gi*gj*gk traversed in Z-order.  Implemented as a table lookup closed over
    the precomputed order (static at trace time)."""
    order = zorder_schedule(gi, gj, gk)
    return lambda s: order[s]


def supersteps(gi: int, gj: int, gk: int, level_bits: int) -> Iterator[List[Tuple[int, int, int]]]:
    """Partition the Z-order traversal into supersteps of 8^level_bits blocks
    (the paper's T = T_1 x ... x T_k multi-granularity time); each superstep
    is a sub-multiplication fitting one cache level."""
    order = zorder_schedule(gi, gj, gk)
    size = 8 ** level_bits
    for s in range(0, len(order), size):
        yield order[s : s + size]
