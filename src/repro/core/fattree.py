"""Recursive fat-tree schedules (Sec. 4.2) from the iterated wreath product.

The base case (Fig. 11): 2x2x2 multiply on 4 processors over 2 steps, with
generators sigma_i, sigma_j, sigma_k mapping onto the fat-tree group
S2^{wr 2} x Z/2Z so that

    processor bits = (k, i)          (C_ki stationary)
    time bit       = i xor j xor k   (each processor runs its two
                                      instructions at distinct steps)

A's position's *high* bit flips every step (crosses the top-level link) and
B's *low* bit flips every step (crosses leaf-level links) -- the minimum
communication for three-words-per-node memory (paper: 4 words over the top
link, 8 over the lower links, counting path segments).

The d-level schedule composes the base case per bit level (the wreath
recursion of Sec. 4.2): processor bits interleave (k_l, i_l) from the top,
and each level contributes an independent time bit tau_l = i_l ^ j_l ^ k_l.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple


def tree_exchange_mask(t: int) -> int:
    """XOR mask of the inter-pod exchange between super-steps t and t+1.

    The recursive schedule walks contraction slabs in the reflected-Gray
    order j = p ^ t, so the slab resident on pod p advances by
    ``t ^ (t + 1)`` -- always of the form 2^(b+1) - 1 where b is the number
    of trailing one-bits of t.  The mask's highest bit is the deepest tree
    level the exchange crosses; the root (level log2(s)) is crossed exactly
    once, at t = s/2 - 1."""
    return t ^ (t + 1)


def tree_exchange_perm(s: int, t: int) -> Tuple[Tuple[int, int], ...]:
    """The pod-axis ppermute realizing the exchange after super-step t: the
    XOR-mask involution d -> d ^ mask on the s pods (pairs swap, so the
    permutation is its own inverse -- every pod both sends and receives its
    A slab shard in one round)."""
    mask = tree_exchange_mask(t)
    return tuple((d, d ^ mask) for d in range(s))


@dataclasses.dataclass(frozen=True)
class FatTreeSchedule:
    """Schedule for n x n x n multiply, n = 2^d, on a fat-tree with n^2 leaves.

    f(i, j, k) -> (processor in [4^d], time in [2^d]); each processor holds
    one element of each of A, B, C at any step (3 words of memory)."""

    d: int

    @property
    def n(self) -> int:
        return 1 << self.d

    @property
    def num_procs(self) -> int:
        return 1 << (2 * self.d)

    @property
    def num_steps(self) -> int:
        return 1 << self.d

    def f(self, i: int, j: int, k: int) -> Tuple[int, int]:
        proc = 0
        time = 0
        for l in range(self.d - 1, -1, -1):
            il, jl, kl = (i >> l) & 1, (j >> l) & 1, (k >> l) & 1
            proc = (proc << 2) | (kl << 1) | il
            time = (time << 1) | (il ^ jl ^ kl)
        return proc, time

    # positions of variable elements at a given step ------------------------
    def pos_A(self, i: int, j: int, time: int) -> int:
        """Processor holding A_ij at ``time``: the k solving tau_l for each
        level is k_l = i_l ^ j_l ^ tau_l."""
        proc = 0
        for l in range(self.d - 1, -1, -1):
            il, jl, tl = (i >> l) & 1, (j >> l) & 1, (time >> l) & 1
            kl = il ^ jl ^ tl
            proc = (proc << 2) | (kl << 1) | il
        return proc

    def pos_B(self, j: int, k: int, time: int) -> int:
        proc = 0
        for l in range(self.d - 1, -1, -1):
            jl, kl, tl = (j >> l) & 1, (k >> l) & 1, (time >> l) & 1
            il = jl ^ kl ^ tl
            proc = (proc << 2) | (kl << 1) | il
        return proc

    def pos_C(self, k: int, i: int) -> int:
        proc = 0
        for l in range(self.d - 1, -1, -1):
            il, kl = (i >> l) & 1, (k >> l) & 1
            proc = (proc << 2) | (kl << 1) | il
        return proc

    # communication accounting ----------------------------------------------
    @functools.cached_property
    def _link_traffic(self) -> Dict[int, int]:
        """The O(n^3 . steps) traffic sweep, computed once per schedule
        (``link_traffic``/``top_level_words``/``level_words`` all read this
        cache; d=3 conformance sweeps assert against it repeatedly)."""
        traffic = {lvl: 0 for lvl in range(1, 2 * self.d + 1)}
        n = self.n
        for time in range(self.num_steps - 1):
            for a in range(n):
                for b in range(n):
                    for (src, dst) in (
                        (self.pos_A(a, b, time), self.pos_A(a, b, time + 1)),
                        (self.pos_B(a, b, time), self.pos_B(a, b, time + 1)),
                    ):
                        if src == dst:
                            continue
                        top = (src ^ dst).bit_length()  # highest differing bit+1
                        for lvl in range(1, top + 1):
                            # a message transits 2 links (up + down) at every
                            # level of its path, including the turning level
                            traffic[lvl] += 2
        return traffic

    def link_traffic(self) -> Dict[int, int]:
        """Words crossing links at each fat-tree level, summed over the run.

        Level L (1 = leaf links, 2d = top) is crossed by a message whose
        source and destination processors first differ at bit (L-1); a
        message crossing level L transits 2 links at every level <= L on its
        up-and-down path; we count *words x links* per level, matching the
        paper's per-level accounting.  Returns a fresh dict; the sweep is
        cached per schedule."""
        return dict(self._link_traffic)

    def level_words(self, level: int) -> int:
        """Words (not words x links) crossing ``level`` over the whole run:
        each word transits 2 links at every level of its path, so the word
        count is half the per-level link traffic."""
        return self._link_traffic[level] // 2

    def top_level_words(self) -> int:
        """Words of A+B crossing the top-level (2d) link over the whole run;
        the paper's claim: n^2 for A (and none for B or C)."""
        return self.level_words(2 * self.d)

    def validate(self) -> bool:
        """Injectivity of f and the 3-words memory bound."""
        n = self.n
        seen = set()
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    key = self.f(i, j, k)
                    if key in seen:
                        return False
                    seen.add(key)
        # every (proc, time) cell used exactly once
        return len(seen) == n ** 3 and n ** 3 == self.num_procs * self.num_steps
