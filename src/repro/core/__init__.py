"""repro.core -- the paper's contribution: symmetry-derived schedules.

Public surface:
  groups         -- cyclic/product/permutation/wreath groups, hex lattice
  homomorphism   -- generator-image homomorphisms + Lemmas 3-5 checks
  schedule       -- TorusSchedule / Torus25DSchedule equivariant maps
  solver         -- enumerate & rank schedules (recovers Cannon et al.)
  cost           -- word/time costs, lower bounds, TPU constants
  fattree        -- recursive wreath-product schedules (Sec. 4.2)
  hexarray       -- systolic hex-array schedule + simulator (Sec. D.2)
  zorder         -- space-bounded schedules as Morton orders (Sec. 4.3)
"""
from . import cost, fattree, groups, hexarray, homomorphism, schedule, solver, zorder
from .cost import perm_link_words
from .schedule import (TorusSchedule, Torus25DSchedule, cannon_schedule,
                       movement_equations_hold, perm_is_bijection,
                       perm_translation, torus_hops)
from .solver import Solution, solve_torus, minimal_hop_cost, is_cannon_like

__all__ = [
    "cost", "fattree", "groups", "hexarray", "homomorphism", "schedule",
    "solver", "zorder", "TorusSchedule", "Torus25DSchedule", "cannon_schedule",
    "torus_hops", "Solution", "solve_torus", "minimal_hop_cost", "is_cannon_like",
    "perm_is_bijection", "perm_translation", "movement_equations_hold",
    "perm_link_words",
]
