"""Equivariant torus schedules for classical matrix multiplication (Sec. 4.1).

A schedule on a q x q torus over t time steps is the equivariant map

    f(X_ijk) = (x0 + i*x1 + j*x2 + k*x3,
                y0 + i*y1 + j*y2 + k*y3,
                t0 + i*t1 + j*t2 + k*t3)        (mod q, q, t)

fixed by the homomorphism generator images M = [[x1,y1,t1],
                                                [x2,y2,t2],
                                                [x3,y3,t3]]  and an anchor.

Each variable set (A on (i,j), B on (j,k), C on (k,i)) moves by a constant
network element mu = (mu_x, mu_y) per time step; the commutative diagram of
Fig. 10 forces, for the *absent* index a of the variable set,

    (x_a, y_a) = t_a * (mu_x, mu_y)      (mod q)

and the initial layout (the paper's l_I at t=t0) is then determined -- for
Cannon this reproduces the classic skewed layout.  ``TorusSchedule`` checks
embedding/injectivity (the image of rho must have full size q^2*t restricted
to the instruction orbit), derives the movement homomorphisms, placements,
and exposes per-step movement vectors consumed by ``repro.dist``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

VarName = str  # "A" | "B" | "C"

# index positions: i=0, j=1, k=2.  Variable -> (present indices, absent index)
VAR_INDEX = {
    "A": ((0, 1), 2),  # A_ij, absent k
    "B": ((1, 2), 0),  # B_jk, absent i
    "C": ((2, 0), 1),  # C_ki, absent j
}


def _inv_mod(a: int, q: int) -> Optional[int]:
    a %= q
    if math.gcd(a, q) != 1:
        return None
    return pow(a, -1, q)


@dataclasses.dataclass(frozen=True)
class TorusSchedule:
    """A candidate schedule; rows of M are the images of the i/j/k shifts."""

    q: int
    t: int
    M: Tuple[Tuple[int, int, int], ...]  # 3 rows of (x, y, tau)
    anchor: Tuple[int, int, int] = (0, 0, 0)

    # -- the equivariant map f ---------------------------------------------
    def f(self, i: int, j: int, k: int) -> Tuple[int, int, int]:
        x0, y0, t0 = self.anchor
        (x1, y1, t1), (x2, y2, t2), (x3, y3, t3) = self.M
        return (
            (x0 + i * x1 + j * x2 + k * x3) % self.q,
            (y0 + i * y1 + j * y2 + k * y3) % self.q,
            (t0 + i * t1 + j * t2 + k * t3) % self.t,
        )

    # -- embedding / injectivity (Sec. 4.1 "image of rho at least q^3") -----
    def is_embedding(self) -> bool:
        """f must be injective on [q]^3 (at most one instruction per
        processor per step, three memory words per node)."""
        if self.t % self.q != 0:
            return False  # Lemma 5
        if self.t == self.q:
            # Linear map Z_q^3 -> Z_q^3: injective iff det invertible mod q.
            (a, b, c), (d, e, f_), (g, h, i_) = self.M
            det = a * (e * i_ - f_ * h) - b * (d * i_ - f_ * g) + c * (d * h - e * g)
            return math.gcd(det % self.q, self.q) == 1
        # general t: brute force (only used for small q in tests)
        seen = set()
        for i in range(self.q):
            for j in range(self.q):
                for k in range(self.q):
                    p = self.f(i, j, k)
                    if p in seen:
                        return False
                    seen.add(p)
        return True

    # -- movement homomorphisms mu per variable set (Fig. 10 constraint) ----
    def movement(self, var: VarName) -> Optional[Tuple[int, int]]:
        """(mu_x, mu_y) network element moving ``var`` each time step, or
        None when the commutative diagram has no solution (schedule invalid
        for this variable set)."""
        _, absent = VAR_INDEX[var]
        xa, ya, ta = self.M[absent]
        tinv = _inv_mod(ta, self.q)
        if tinv is None:
            # t_a not invertible: need (x_a, y_a) == 0 as well, and then the
            # variable would be needed at 2+ places at the same step => only
            # consistent if it never moves AND placement is replicated; the
            # single-copy model forbids that unless (x_a,y_a)=(0,0)=t_a.
            if (xa % self.q, ya % self.q) == (0, 0) and ta % self.t == 0:
                return (0, 0)
            return None
        return ((xa * tinv) % self.q, (ya * tinv) % self.q)

    def movements(self) -> Optional[Dict[VarName, Tuple[int, int]]]:
        out = {}
        for v in ("A", "B", "C"):
            mv = self.movement(v)
            if mv is None:
                return None
            out[v] = mv
        return out

    # -- initial data placement l_I at time t0 ------------------------------
    def placement(self, var: VarName) -> Optional[np.ndarray]:
        """q x q array: placement[r, s] = (x, y) of variable element (r, s)
        at the anchor time step t0.  Solves f's time row for the absent index
        such that the instruction touching (r,s) runs at t0."""
        if self.t != self.q:
            return None  # placements only materialized for the t = q family
        (p0, p1), absent = VAR_INDEX[var]
        _, _, ta = self.M[absent]
        tinv = _inv_mod(ta, self.q)
        if tinv is None:
            return None
        x0, y0, t0 = self.anchor
        out = np.zeros((self.q, self.q, 2), dtype=np.int64)
        for r in range(self.q):
            for s in range(self.q):
                idx = [0, 0, 0]
                idx[p0], idx[p1] = r, s
                # residual time owed to the two present indices
                tpart = (idx[0] * self.M[0][2] + idx[1] * self.M[1][2]
                         + idx[2] * self.M[2][2])
                # solve t0 + tpart + a*ta == t0  (mod q)  for absent exponent a
                a = (-tpart * tinv) % self.q
                idx[absent] = a
                x, y, _ = self.f(*idx)
                out[r, s] = (x, y)
        return out

    # -- lowering hooks consumed by repro.dist ------------------------------
    # A torus device (x, y) flattens to x * q + y -- row-major with the first
    # mesh axis major, matching jax.lax.ppermute over a ("x", "y") axis tuple.
    # "Canonical" layout is the matrix-block layout under PartitionSpec(x, y):
    # A_ij at (i, j) and B_jk at (j, k) match their paper coordinates (r, s),
    # but the output C is indexed (k, i) in the paper while its matrix block
    # row is i -- so C's canonical device is the swap (s, r).

    def _canonical_device(self, var: VarName, r: int, s: int) -> Tuple[int, int]:
        return (s, r) if var == "C" else (r, s)

    def movement_perm(self, var: VarName) -> Optional[list]:
        """(src, dst) flat-device pairs for ONE time step of ``var``: the
        block on node nu moves to nu + mu.  This is the literal ``perm``
        argument repro.dist feeds to ppermute each step."""
        mv = self.movement(var)
        if mv is None:
            return None
        mx, my = mv
        q = self.q
        return [
            (x * q + y, ((x + mx) % q) * q + (y + my) % q)
            for x in range(q)
            for y in range(q)
        ]

    def placement_perm(self, var: VarName) -> Optional[list]:
        """(src, dst) flat-device pairs taking the canonical block layout
        (block (r, s) on device (r, s), i.e. PartitionSpec(x, y)) to the
        schedule's initial placement l_I -- Cannon's skew, executed as a
        single ppermute over the flattened (x, y) axes."""
        pl = self.placement(var)
        if pl is None:
            return None
        q = self.q
        pairs = []
        for r in range(q):
            for s in range(q):
                cx, cy = self._canonical_device(var, r, s)
                pairs.append((cx * q + cy, int(pl[r, s, 0]) * q + int(pl[r, s, 1])))
        return pairs

    def collection_perm(self, var: VarName, after_steps: int) -> Optional[list]:
        """Inverse layout ppermute: (src, dst) pairs returning ``var`` from
        its position after ``after_steps`` movement steps back to the
        canonical block layout.  Identity perms are returned as [] so the
        executor can skip the collective."""
        pl = self.placement(var)
        mv = self.movement(var)
        if pl is None or mv is None:
            return None
        q = self.q
        pairs = []
        identity = True
        for r in range(q):
            for s in range(q):
                x = (int(pl[r, s, 0]) + after_steps * mv[0]) % q
                y = (int(pl[r, s, 1]) + after_steps * mv[1]) % q
                cx, cy = self._canonical_device(var, r, s)
                if (x, y) != (cx, cy):
                    identity = False
                pairs.append((x * q + y, cx * q + cy))
        return [] if identity else pairs

    # -- cost hooks ----------------------------------------------------------
    def hop_cost(self, var: VarName) -> Optional[int]:
        mv = self.movement(var)
        if mv is None:
            return None
        return torus_hops(mv, self.q)

    def total_hop_cost(self) -> Optional[int]:
        """Sum over A,B,C of per-step hop counts (the solver's objective)."""
        tot = 0
        for v in ("A", "B", "C"):
            h = self.hop_cost(v)
            if h is None:
                return None
            tot += h
        return tot

    def validate(self) -> bool:
        """Full validity: embedding + all three diagrams solvable + every
        processor touches exactly one C element (single-copy memory)."""
        if not self.is_embedding():
            return False
        if self.movements() is None:
            return False
        for v in ("A", "B", "C"):
            pl = self.placement(v)
            if pl is None:
                return False
            # single copy: placement must be a bijection onto the torus
            flat = {tuple(p) for row in pl for p in row}
            if len(flat) != self.q * self.q:
                return False
        return True


def torus_hops(vec: Tuple[int, int], q: int) -> int:
    """Minimal hop count of a torus translation (wrap-around metric)."""
    dx, dy = vec[0] % q, vec[1] % q
    return min(dx, q - dx) + min(dy, q - dy)


# ---------------------------------------------------------------------------
# Equivariance predicates on lowered (src, dst) device permutations.
#
# These are the machine-checkable halves of the paper's algebra, consumed by
# ``repro.verify.conformance``: a ppermute emitted by an equivariant schedule
# must be (a) a bijection on the torus and (b) a *translation* -- the image
# of the movement homomorphism mu commutes with the torus action, so every
# (src, dst) pair realizes the same network element.
# ---------------------------------------------------------------------------


def perm_is_bijection(perm, size: int) -> bool:
    """``perm`` (pairs of flat device ids, identity pairs may be elided)
    extends to a bijection on [size]: listed sources and destinations are
    distinct and within range."""
    perm = tuple(perm)
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
        return False
    if any(not (0 <= v < size) for v in srcs + dsts):
        return False
    # elided identity pairs must not collide with listed endpoints
    elided = set(range(size)) - set(srcs)
    return elided == set(range(size)) - set(dsts)


def perm_translation(perm, q: int) -> Optional[Tuple[int, int]]:
    """The constant torus translation mu realized by ``perm`` over the
    flattened q x q torus (flat id = x * q + y), or None when the pairs do
    not share one -- i.e. the permutation is NOT the image of a movement
    homomorphism and the schedule's commutative diagram is violated."""
    perm = tuple(perm)
    mu = None
    for src, dst in perm:
        sx, sy = divmod(int(src), q)
        dx, dy = divmod(int(dst), q)
        step = ((dx - sx) % q, (dy - sy) % q)
        if mu is None:
            mu = step
        elif step != mu:
            return None
    # identity pairs elided from the listing are only consistent with mu = 0
    if mu is not None and mu != (0, 0) and len(perm) != q * q:
        return None
    return mu if mu is not None else (0, 0)


def movement_equations_hold(sched: TorusSchedule,
                            moves: Optional[Dict[VarName, Tuple[int, int]]]
                            = None) -> bool:
    """Fig.-10 commutative diagram: each variable set's per-step network
    element mu must satisfy (x_a, y_a) == t_a * mu (mod q) for the absent
    index a.  ``moves`` are the movement vectors to test -- pass the mus
    recovered from an *executed* program's permutations to verify it
    against the schedule's algebra (the discriminating use; with the
    schedule's own derived movements the equations hold by construction
    whenever they are solvable)."""
    if moves is None:
        moves = sched.movements()
    if moves is None:
        return False
    for var in ("A", "B", "C"):
        if var not in moves:
            return False
        mx, my = moves[var]
        _, absent = VAR_INDEX[var]
        xa, ya, ta = sched.M[absent]
        if (ta * mx - xa) % sched.q or (ta * my - ya) % sched.q:
            return False
    return True


def cannon_schedule(q: int) -> TorusSchedule:
    """The classical Cannon solution recovered in Sec. 4.1.

    C_ki stationary at P_{i,k}; time advances with every index; A moves one
    hop in -y, B one hop in -x per step; the induced initial placement is the
    classic skew  A_ij -> P_{i, j-i},  B_jk -> P_{j-k, k}.
    """
    return TorusSchedule(
        q=q,
        t=q,
        M=(
            (1, 0, -1 % q),  # image of i-shift
            (0, 0, 1),       # image of j-shift (contraction advances time)
            (0, 1, -1 % q),  # image of k-shift
        ),
    )


# ---------------------------------------------------------------------------
# 2.5D schedule on the q x q x c torus (Sec. D.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Torus25DSchedule:
    """Equivariant schedule for the (Z/qZ)^2 x Z/cZ network of Sec. D.1.

    The contraction index j is split j = j_c * (q/c) + j_t; the c-part maps to
    the z axis (g_z) -- each of the c layers owns a contraction slab and a
    full copy of A and B (c-fold replication, Sec. 2.5) -- while the t-parts
    run a skewed Cannon inside each q x q layer for t = q/c steps.  C is
    computed as partial sums per layer and reduced over z at the end ("a
    suitable replication at the beginning and a reduction of C at the end").
    """

    q: int
    c: int

    def __post_init__(self):
        assert self.q % self.c == 0

    @property
    def t(self) -> int:
        return self.q // self.c

    def f(self, i: int, j: int, k: int) -> Tuple[int, int, int, int]:
        """(x, y, z, step) for the blocked instruction (i, j, k) in [q]^2x[q].

        Uses the rho' of Sec. D.1: i_t -> (g_x, -dt); j_t -> (e, dt);
        k_t -> (g_y, -dt); j_c -> g_z; i_c, k_c -> identity (they only select
        blocks within a node).
        """
        jc, jt = divmod(j, self.t)
        x = i % self.q
        y = k % self.q
        z = jc % self.c
        step = (jt - i - k) % self.t
        return (x, y, z, step)

    def layer_contraction_slab(self, z: int) -> Tuple[int, int]:
        """[lo, hi) of contraction indices owned by layer z."""
        return (z * self.t, (z + 1) * self.t)

    def replication_factor(self) -> int:
        return self.c

    def comm_words_per_node(self, n: int, p: int) -> float:
        """Analytic per-node communication of the 2.5D schedule for an
        n x n x n multiply on p = q*q*c nodes: O(n^2 / sqrt(c*p)) words
        moved per node during the Cannon phase, plus the c-fold replication
        broadcast and final reduction (n^2/p words each, amortized)."""
        q = self.q
        t = self.t
        block = (n / q) ** 2  # words per block per variable
        shift_words = 2 * block * max(t - 1, 0)  # A and B one-hop shifts
        repl_words = 2 * block * (self.c - 1) / self.c  # initial broadcast
        reduce_words = block * (self.c - 1) / self.c  # C reduction over z
        return shift_words + repl_words + reduce_words
