"""Pure-jnp oracle for the Z-order matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with fp32 accumulation, matching the kernel's contract."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)
