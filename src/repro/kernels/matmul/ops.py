"""jit'd public wrapper for the Z-order matmul kernel.

Handles arbitrary shapes by padding to block multiples, chooses VMEM-fitting
MXU-aligned blocks, and falls back to the jnp oracle for shapes too small to
tile (the kernel is a throughput kernel; tiny matmuls belong to XLA).

With ``repro.obs`` tracing enabled, eager (non-traced) calls are wrapped in
a ``kernel.matmul`` span: wall time (block_until_ready'd) lands in the
``kernel.matmul.us`` histogram and achieved FLOPs are recorded against the
roofline peak (``kernel.matmul.roofline_fraction``).  Disabled mode and
calls under tracing (tracer operands inside shard_map/jit bodies) go
straight to the jit'd kernel with zero added work.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.cost import PEAK_FLOPS_BF16

from .kernel import default_blocks, zorder_matmul
from .ref import matmul_ref

_MIN_TILE = 128


def _resolve_blocks(m, n, k, dtype_bytes, out_dtype_bytes,
                    block_m, block_n, block_k):
    """The block shapes the kernel will actually run: VMEM-fitting defaults
    sized by the real input/output byte widths, explicit overrides winning,
    everything clamped to the problem dims.  Shared by the jit'd kernel path
    and the eager pad-waste accounting so both see the same blocks."""
    bm, bn, bk = default_blocks(m, n, k, dtype_bytes, out_dtype_bytes)
    bm, bn, bk = block_m or bm, block_n or bn, block_k or bk
    return min(bm, m), min(bn, n), min(bk, k)


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    order: str = "zorder",
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Z-order Pallas matmul (see module docstring); obs-instrumented."""
    kw = dict(block_m=block_m, block_n=block_n, block_k=block_k,
              order=order, interpret=interpret, out_dtype=out_dtype)
    if not obs.enabled() or isinstance(a, jax.core.Tracer) \
            or isinstance(b, jax.core.Tracer):
        return _matmul_jit(a, b, **kw)
    m, k = a.shape
    n = b.shape[1]
    with obs.span("kernel.matmul", m=m, n=n, k=k, order=order):
        t0 = time.perf_counter()
        out = _matmul_jit(a, b, **kw)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
    flops = 2.0 * m * n * k
    obs.histogram("kernel.matmul.us").observe(dt * 1e6)
    obs.counter("kernel.matmul.flops").inc(flops)
    obs.histogram("kernel.matmul.roofline_fraction").observe(
        flops / dt / PEAK_FLOPS_BF16)
    if min(m, n, k) >= _MIN_TILE:
        # ragged shapes are padded to block multiples silently inside the
        # jit; surface the overhead as padded FLOPs / useful FLOPs
        dbytes = jnp.dtype(a.dtype).itemsize
        obytes = jnp.dtype(out_dtype or a.dtype).itemsize
        bm, bn, bk = _resolve_blocks(m, n, k, dbytes, obytes,
                                     block_m, block_n, block_k)
        padded = (m + (-m) % bm) * (n + (-n) % bn) * (k + (-k) % bk)
        obs.histogram("kernel.pad_waste").observe(padded / (m * n * k))
    return out


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "order", "interpret",
                     "out_dtype"),
)
def _matmul_jit(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    order: str = "zorder",
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    if min(m, n, k) < _MIN_TILE:
        return matmul_ref(a, b, out_dtype=out_dtype)
    dbytes = jnp.dtype(a.dtype).itemsize
    obytes = jnp.dtype(out_dtype or a.dtype).itemsize
    bm, bn, bk = _resolve_blocks(m, n, k, dbytes, obytes,
                                 block_m, block_n, block_k)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = zorder_matmul(
        ap, bp, block_m=bm, block_n=bn, block_k=bk, order=order,
        interpret=interpret, out_dtype=out_dtype or a.dtype,
    )
    if pm or pn:
        out = out[:m, :n]
    return out
