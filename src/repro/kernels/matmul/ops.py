"""jit'd public wrapper for the Z-order matmul kernel.

Handles arbitrary shapes by padding to block multiples, chooses VMEM-fitting
MXU-aligned blocks, and falls back to the jnp oracle for shapes too small to
tile (the kernel is a throughput kernel; tiny matmuls belong to XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import default_blocks, zorder_matmul
from .ref import matmul_ref

_MIN_TILE = 128


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "order", "interpret",
                     "out_dtype"),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    order: str = "zorder",
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    if min(m, n, k) < _MIN_TILE:
        return matmul_ref(a, b, out_dtype=out_dtype)
    dbytes = jnp.dtype(a.dtype).itemsize
    bm, bn, bk = default_blocks(m, n, k, dbytes)
    bm, bn, bk = block_m or bm, block_n or bn, block_k or bk
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = zorder_matmul(
        ap, bp, block_m=bm, block_n=bn, block_k=bk, order=order,
        interpret=interpret, out_dtype=out_dtype or a.dtype,
    )
    if pm or pn:
        out = out[:m, :n]
    return out
