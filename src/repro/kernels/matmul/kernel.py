"""Z-order (space-bounded) blocked matmul Pallas TPU kernel.

This is the Sec.-4.3 level of the paper mapped onto the TPU memory
hierarchy: the HBM -> VMEM block schedule follows the iterated-wreath-product
(Morton / Z-order) traversal over the (i, j) output-block grid, which is the
cache-oblivious order -- each VMEM-resident A-row-panel and B-column-panel is
reused across neighbouring output blocks at every "virtual cache level"
simultaneously.  The contraction axis k stays innermost (contiguous revisits
of the output block are required for legal accumulation on TPU, and k is the
"time" axis of the systolic MXU -- the paper's Delta).

Hardware adaptation notes (DESIGN.md Sec. 2): block shapes are multiples of
the 128-wide MXU/VREG tiling; the fp32 accumulator lives in a VMEM scratch so
low-precision inputs (bf16) accumulate at full precision.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.zorder import zorder_schedule


def _matmul_kernel(oi_ref, oj_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    del oi_ref, oj_ref  # consumed by the index maps (scalar prefetch)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def zorder_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    order: str = "zorder",
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with a Z-order HBM->VMEM block schedule.

    a: (m, k), b: (k, n); m, n, k must be divisible by the block sizes
    (``ops.matmul`` pads arbitrary shapes before calling this).
    order: "zorder" (paper Sec. 4.3 schedule) or "rowmajor" (baseline).
    """
    m, kdim = a.shape
    k2, n = b.shape
    assert kdim == k2, f"contraction mismatch {kdim} vs {k2}"
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0, (
        f"shape ({m},{kdim},{n}) not divisible by blocks "
        f"({block_m},{block_k},{block_n})"
    )
    out_dtype = out_dtype or a.dtype
    gm, gn, gk = m // block_m, n // block_n, kdim // block_k

    if order == "zorder":
        ij_order = [(i, j) for (i, j, _z) in zorder_schedule(gm, gn, 1)]
    elif order == "rowmajor":
        ij_order = [(i, j) for i in range(gm) for j in range(gn)]
    else:
        raise ValueError(f"unknown order {order!r}")
    oi = jnp.asarray([i for i, _ in ij_order], dtype=jnp.int32)
    oj = jnp.asarray([j for _, j in ij_order], dtype=jnp.int32)

    # The block-visit order is data the index maps must read: this is what
    # scalar prefetch is for on TPU (the table sits in SMEM ahead of the grid).
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(gm * gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda s, k, oi, oj: (oi[s], k)),
            pl.BlockSpec((block_k, block_n), lambda s, k, oi, oj: (k, oj[s])),
        ],
        out_specs=pl.BlockSpec(
            (block_m, block_n), lambda s, k, oi, oj: (oi[s], oj[s])
        ),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=gk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(oi, oj, a, b)


def vmem_working_set_bytes(
    block_m: int, block_n: int, block_k: int, dtype_bytes: int = 2,
    out_dtype_bytes: int | None = None,
) -> int:
    """VMEM bytes claimed by one grid step (A, B blocks + fp32 acc + out).

    ``dtype_bytes`` is the *input* element width; the output block is sized
    by ``out_dtype_bytes`` when it differs (the accumulator is always fp32).
    Must fit the ~128 MiB v5e VMEM with double-buffering headroom (x2 on the
    streamed inputs)."""
    a = block_m * block_k * dtype_bytes * 2  # double-buffered
    b = block_k * block_n * dtype_bytes * 2
    acc = block_m * block_n * 4
    out = block_m * block_n * (out_dtype_bytes or dtype_bytes)
    return a + b + acc + out


def default_blocks(m: int, n: int, k: int, dtype_bytes: int = 2,
                   out_dtype_bytes: int | None = None) -> Tuple[int, int, int]:
    """Pick MXU-aligned blocks that fit VMEM; prefers large k blocks (the
    contraction reuse direction) then square-ish (m, n)."""
    bm = min(256, max(128, m))
    bn = min(256, max(128, n))
    bk = min(2048, max(128, k))
    while vmem_working_set_bytes(bm, bn, bk, dtype_bytes,
                                 out_dtype_bytes) > 96 * 1024 * 1024:
        if bk > 256:
            bk //= 2
        elif bm >= bn and bm > 128:
            bm //= 2
        elif bn > 128:
            bn //= 2
        else:
            break
    return bm, bn, bk
