from .kernel import default_blocks, vmem_working_set_bytes, zorder_matmul
from .ops import matmul
from .ref import matmul_ref

__all__ = [
    "default_blocks", "vmem_working_set_bytes", "zorder_matmul",
    "matmul", "matmul_ref",
]
