"""Pure-jnp oracle for flash attention (materializes the score matrix)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """q: (BH_q, S_q, D); k, v: (BH_kv, S_kv, D).  fp32 softmax, GQA by
    repeating KV heads."""
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    group = bhq // bhkv
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask = mask & (cols <= rows)
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)
