from .kernel import flash_attention
from .ops import mha
from .ref import attention_ref

__all__ = ["flash_attention", "mha", "attention_ref"]
