"""Flash attention (online-softmax) Pallas TPU kernel.

Used by the 32k-prefill shapes: attention scores for a 32k sequence do not
fit HBM comfortably (S^2 bf16 = 2 GiB per head) and never fit VMEM, so the
kernel streams KV blocks through VMEM keeping running max / normalizer /
accumulator scratch -- the standard IO-aware schedule, which in this repo's
terms is the Sec.-4.3 space-bounded schedule applied to the (softmax-fused)
attention contraction: the kv axis is the "time" group Delta, q x head blocks
are the processor-like axis.

Supports causal masking, sliding-window (h2o-danube SWA), and GQA via an
index-map head mapping (no KV duplication in HBM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, nkv: int, block_q: int, block_kv: int, causal: bool, window: int,
    scale: float,
):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv
    # Static-shape dynamic skip: block contributes unless fully masked.
    needed = jnp.asarray(True)
    if causal:
        needed = jnp.logical_and(needed, kv_start <= q_start + block_q - 1)
    if window > 0:
        # keys older than (q_idx - window + 1) are masked; the youngest query
        # in this block is q_start + block_q - 1
        needed = jnp.logical_and(
            needed, kv_start + block_kv - 1 >= q_start - window + 1
        )

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                               # (bq, bkv)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window > 0:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)       # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        p = jnp.exp(s - m_new)                          # (bq, bkv)
        p = jnp.where(mask, p, 0.0)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                # (bkv, d)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nkv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH_q, S_q, D); k, v: (BH_kv, S_kv, D) with BH_q % BH_kv == 0
    (GQA group = BH_q // BH_kv, resolved in the KV index maps).

    Returns (BH_q, S_q, D).  S dims must divide the block sizes (ops pads).
    """
    bhq, sq, d = q.shape
    bhkv, skv, dk = k.shape
    assert d == dk and v.shape == k.shape and bhq % bhkv == 0
    group = bhq // bhkv
    assert sq % block_q == 0 and skv % block_kv == 0
    nq, nkv = sq // block_q, skv // block_kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, nkv=nkv, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(bhq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, iq, ik: (h // group, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, iq, ik: (h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
