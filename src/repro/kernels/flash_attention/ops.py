"""jit'd public wrapper for flash attention.

Accepts the model-layer layout (B, S, H, D), handles GQA head mapping,
pads sequence lengths to block multiples (padding keys are masked by the
causal/window logic plus an explicit length guard), and falls back to the
oracle for tiny shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref

_MIN_SEQ = 256


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape

    def to_heads(x):  # (B, S, H, D) -> (B*H, S, D)
        return x.transpose(0, 2, 1, 3).reshape(-1, x.shape[1], x.shape[3])

    def from_heads(x, h):  # (B*H, S, D) -> (B, S, H, D)
        return x.reshape(b, h, x.shape[1], d).transpose(0, 2, 1, 3)

    qh, kh, vh = to_heads(q), to_heads(k), to_heads(v)
    if sq < _MIN_SEQ or skv < _MIN_SEQ:
        return from_heads(
            attention_ref(qh, kh, vh, causal=causal, window=window), hq
        )

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    pq, pkv = (-sq) % bq, (-skv) % bkv
    if pq:
        qh = jnp.pad(qh, ((0, 0), (0, pq), (0, 0)))
    if pkv:
        kh = jnp.pad(kh, ((0, 0), (0, pkv), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pkv), (0, 0)))
    # padded keys must never be attended to: with causal=True the padded
    # queries are the only ones that can see them; for the non-causal case
    # guard explicitly by masking via a huge negative bias on padded keys.
    if pkv and not causal:
        raise NotImplementedError("non-causal padding not needed by the models")
    out = flash_attention(
        qh, kh, vh, causal=causal, window=window,
        block_q=bq, block_kv=bkv, interpret=interpret,
    )
    if pq:
        out = out[:, :sq]
    return from_heads(out, hq)
