"""Pallas TPU kernels for the compute hot-spots.

  matmul          -- Z-order (space-bounded, Sec. 4.3) blocked matmul
  flash_attention -- online-softmax attention for long-context prefill

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes in
interpret mode against the oracle.
"""
from . import flash_attention, matmul

__all__ = ["flash_attention", "matmul"]
