"""Typed counters and histograms with a process-global registry.

Instrumentation sites guard on ``repro.obs.enabled()`` before recording, so
the registry only fills while tracing is on; direct use (tests, benches)
works regardless.  ``snapshot()`` flattens everything into the metrics JSON
``benchmarks/run.py --report`` consumes.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple


class Counter:
    """Monotonic labeled counter: ``counter("plan.cache.hit").inc()`` or
    ``counter("dist.collective.bytes").inc(n, kind="ppermute")``.  Values
    are kept per label set (sorted key=value pairs)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._values: Dict[Tuple, float] = {}

    @staticmethod
    def _key(labels: Dict[str, Any]) -> Tuple:
        return tuple(sorted((k, str(v)) for k, v in labels.items()))

    def inc(self, value: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())

    def items(self):
        with self._lock:
            return dict(self._values)


class Histogram:
    """Streaming summary (count/sum/min/max) -- enough for build-µs and
    kernel wall-time distributions without storing every sample."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def summary(self) -> Dict[str, Optional[float]]:
        with self._lock:
            mean = self.sum / self.count if self.count else None
            return {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max, "mean": mean}


_LOCK = threading.Lock()
_COUNTERS: Dict[str, Counter] = {}
_HISTOGRAMS: Dict[str, Histogram] = {}


def counter(name: str) -> Counter:
    """Get-or-create the named process-global counter."""
    with _LOCK:
        c = _COUNTERS.get(name)
        if c is None:
            c = _COUNTERS[name] = Counter(name)
        return c


def histogram(name: str) -> Histogram:
    """Get-or-create the named process-global histogram."""
    with _LOCK:
        h = _HISTOGRAMS.get(name)
        if h is None:
            h = _HISTOGRAMS[name] = Histogram(name)
        return h


def reset_metrics() -> None:
    """Drop every registered counter and histogram."""
    with _LOCK:
        _COUNTERS.clear()
        _HISTOGRAMS.clear()


def snapshot() -> Dict[str, Any]:
    """Flatten the registry: ``{name: total}`` for unlabeled counters,
    ``{name{k=v,...}: value}`` per label set otherwise, and the
    count/sum/min/max/mean summary per histogram."""
    out: Dict[str, Any] = {}
    with _LOCK:
        counters = list(_COUNTERS.values())
        hists = list(_HISTOGRAMS.values())
    for c in counters:
        items = c.items()
        for key, val in sorted(items.items()):
            if not key:
                out[c.name] = val
            else:
                lbl = ",".join(f"{k}={v}" for k, v in key)
                out[f"{c.name}{{{lbl}}}"] = val
    for h in hists:
        out[h.name] = h.summary()
    return out
