"""Versioned machine profiles: measured α–β link parameters for the planner.

A :class:`MachineProfile` is what a calibration run
(``repro.obs.calibrate.probe_links`` / ``python -m repro.launch.perf_probe``)
persists: per link class, the fitted per-message latency α (seconds) and
bandwidth β⁻¹ (bytes/s), plus the measured peak matmul FLOPs.  The planner
(``build_plan(profile=...)`` → ``rank_mesh_strategies``) then ranks
strategies by **calibrated seconds** -- ``core.cost.calibrated_total_s``
applied to the analytic ``Estimate``'s word counts and message counts --
while the word counts themselves stay analytic, so the conformance harness
keeps checking exact words.

Profiles are frozen/hashable (they participate in the plan-cache key) and
serialize to schema-versioned JSON (``save``/``load``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

PROFILE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class LinkParams:
    """Fitted α–β model of one link class: transfer time for ``b`` bytes is
    ``alpha_s + b / bw_bytes_per_s``."""

    alpha_s: float
    bw_bytes_per_s: float

    def seconds(self, num_bytes: float, msgs: float = 1) -> float:
        return msgs * self.alpha_s + num_bytes / self.bw_bytes_per_s


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """Calibrated machine parameters the planner ranks with.

    ``tuning`` optionally embeds a ``repro.tune.TuningTable`` (the
    ``perf_probe --tune`` artifact): ``build_plan(profile=...)`` then
    prices the compute side with measured kernel seconds wherever the
    table covers the local bucket, alongside the fitted α–β comm terms --
    the repo's two calibration loops in one ranking."""

    platform: str
    peak_flops: float
    links: Tuple[Tuple[str, LinkParams], ...]
    created: str = ""
    schema: int = PROFILE_SCHEMA
    tuning: Optional[object] = None  # repro.tune.TuningTable (lazy import)

    def link(self, name: str = "ici") -> LinkParams:
        """Params for ``name``, falling back to the first link class (a
        profile with any measurement beats no profile)."""
        for n, p in self.links:
            if n == name:
                return p
        if self.links:
            return self.links[0][1]
        raise ValueError(f"profile has no link classes (wanted {name!r})")

    def seconds(self, est, link: str = "ici", *,
                compute_s: Optional[float] = None) -> float:
        """Calibrated total seconds for an analytic ``dist.api.Estimate``:
        compute from the measured peak FLOPs, communication from the fitted
        α–β applied to the estimate's bytes and message count, combined
        with the estimate's own overlap rule.  ``compute_s`` substitutes a
        measured compute time (tuned kernel seconds -- the planner derives
        it from ``tuning`` per local shape) for the roofline term.

        When the estimate carries per-axis terms (``est.comm_by_axis``) AND
        this profile has a fitted ``axis:{name}`` link class for *every*
        axis in them, each axis's bytes/messages are priced with its own
        α–β and summed -- heterogeneous multi-axis meshes rank correctly.
        Otherwise the pooled ``link`` class prices the totals, preserving
        the ``default_profile`` analytic-ranking identity."""
        from repro.core.cost import calibrated_total_s

        lp = self.link(link)
        names = {n for n, _ in self.links}
        terms = None
        by_axis = getattr(est, "comm_by_axis", ())
        if by_axis and all(f"axis:{ax}" in names for ax, _, _ in by_axis):
            terms = tuple(
                (self.link(f"axis:{ax}").alpha_s,
                 self.link(f"axis:{ax}").bw_bytes_per_s, b, ms)
                for ax, b, ms in by_axis)
        return calibrated_total_s(
            2.0 * est.m * est.n * est.k / max(est.tp, 1),
            est.comm_bytes, est.msgs,
            alpha_s=lp.alpha_s, bw_bytes_per_s=lp.bw_bytes_per_s,
            peak_flops=self.peak_flops, overlapped=est.overlapped,
            comm_terms=terms, compute_s=compute_s)

    def to_json(self) -> Dict:
        obj = {
            "schema": self.schema,
            "platform": self.platform,
            "peak_flops": self.peak_flops,
            "created": self.created,
            "links": {n: {"alpha_s": p.alpha_s,
                          "bw_bytes_per_s": p.bw_bytes_per_s}
                      for n, p in self.links},
        }
        if self.tuning is not None:
            obj["tuning"] = self.tuning.to_json()
        return obj

    @classmethod
    def from_json(cls, obj: Dict) -> "MachineProfile":
        schema = int(obj.get("schema", 0))
        if schema > PROFILE_SCHEMA:
            raise ValueError(
                f"machine profile schema {schema} is newer than supported "
                f"{PROFILE_SCHEMA}; re-run calibration")
        tuning = None
        if obj.get("tuning"):
            # lazy import: repro.tune is jax-adjacent and cyclic with obs
            from repro.tune.table import TuningTable

            tuning = TuningTable.from_json(obj["tuning"])
        return cls(
            platform=obj.get("platform", "unknown"),
            peak_flops=float(obj["peak_flops"]),
            links=tuple(sorted(
                (n, LinkParams(float(p["alpha_s"]),
                               float(p["bw_bytes_per_s"])))
                for n, p in obj.get("links", {}).items())),
            created=obj.get("created", ""),
            schema=schema or PROFILE_SCHEMA,
            tuning=tuning,
        )


def save_profile(profile: MachineProfile, path: str) -> str:
    with open(path, "w") as f:
        json.dump(profile.to_json(), f, indent=1, sort_keys=True)
    return path


def load_profile(path: str) -> MachineProfile:
    with open(path) as f:
        return MachineProfile.from_json(json.load(f))


def default_profile() -> MachineProfile:
    """The analytic TPU constants as a profile (α = 0): ranking with it
    reproduces the uncalibrated cost model exactly -- the identity the
    tests pin."""
    from repro.core import cost as _cost

    return MachineProfile(
        platform="analytic",
        peak_flops=_cost.PEAK_FLOPS_BF16,
        links=(("ici", LinkParams(0.0, _cost.ICI_BW)),),
    )


def fit_alpha_beta(sizes_bytes, times_s) -> LinkParams:
    """Least-squares fit of ``t = α + bytes / bw`` over measured
    (bytes, seconds) points.  α is clamped to ≥ 0 and bw to > 0 so noisy
    microbenchmarks can never produce a nonsensical profile."""
    xs = [float(x) for x in sizes_bytes]
    ys = [float(y) for y in times_s]
    if len(xs) != len(ys) or not xs:
        raise ValueError("need equal, nonempty sizes/times")
    n = len(xs)
    if n == 1 or max(xs) == min(xs):
        # one point: attribute everything to bandwidth
        return LinkParams(0.0, max(xs[0] / max(ys[0], 1e-12), 1.0))
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    slope = sxy / sxx if sxx else 0.0  # seconds per byte
    alpha = my - slope * mx
    if slope <= 0:
        # latency-flat regime: charge the mean time as pure latency
        return LinkParams(max(my, 0.0), 1e15)
    return LinkParams(max(alpha, 0.0), 1.0 / slope)
