"""Span recorder: hierarchical, context-propagated, zero-dependency.

The process-global :class:`Recorder` collects three event kinds:

  spans        -- ``with span("plan.build", m=..., n=...):`` blocks; nesting
                  is tracked per thread (a thread-local stack), and every
                  span inherits the *tags* of its ancestors so a collective
                  recorded three layers under ``plan.execute`` still knows
                  which strategy it belongs to.
  collectives  -- one :class:`CollectiveEvent` per data-movement call routed
                  through the ``repro.dist._collectives`` seam, keyed exactly
                  like ``repro.verify.trace.CollectiveRecord`` (kind, group,
                  shard words, canonical perm) so the obs multiset is
                  bitwise-comparable to the conformance interceptor's.
  instants     -- point annotations (cache hits, ranking decisions).

Disabled mode (the default) is a no-op fast path: ``span()`` returns a
shared singleton context manager that allocates nothing, and every
instrumentation site guards on ``enabled()`` (one module-global read)
before touching the recorder.  ``observe()`` is the scoped enable used by
tests, drift checks, and the benchmark driver.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

Perm = Tuple[Tuple[int, int], ...]

_ENABLED = False


def enabled() -> bool:
    """True when the observability layer is recording (module-global flag;
    the one check every instrumentation site pays when tracing is off)."""
    return _ENABLED


def enable() -> None:
    """Turn span/collective recording on (process-global)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn recording off; already-captured events stay in the recorder."""
    global _ENABLED
    _ENABLED = False


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


def canonical_perm(perm) -> Perm:
    """Sorted non-identity (src, dst) pairs -- the same comparable form
    ``repro.verify.trace.canonical_perm`` uses (duplicated here so the
    dist seam never imports the verify package)."""
    return tuple(sorted(
        (int(s), int(d)) for s, d in perm if int(s) != int(d)))


@dataclasses.dataclass
class SpanRecord:
    """One finished span: a Perfetto complete ("X") event."""

    name: str
    ts_us: float
    dur_us: float
    tid: int
    depth: int
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class CollectiveEvent:
    """One data-movement collective seen at the dist seam.

    ``key`` matches ``repro.verify.trace.CollectiveRecord.key`` exactly, so
    ``Counter(ev.key for ev in recorder.collectives)`` is directly
    comparable to the conformance interceptor's multiset.
    """

    kind: str                     # "ppermute" | "all_gather" | "psum"
    group: int
    shard_words: int
    perm: Optional[Perm] = None   # canonical, ppermute only
    strategy: str = ""            # ambient span tag at record time
    comm: str = "exposed"         # "hidden" when issued as a prefetch
    ts_us: float = 0.0
    tid: int = 0

    @property
    def key(self) -> Tuple:
        return (self.kind, self.group, self.shard_words, self.perm)


class Recorder:
    """Thread-safe process-global sink for spans/collectives/instants."""

    def __init__(self):
        self._lock = threading.Lock()
        self.spans: List[SpanRecord] = []
        self.collectives: List[CollectiveEvent] = []
        self.instants: List[Tuple[str, float, int, Dict[str, Any]]] = []

    def add_span(self, rec: SpanRecord) -> None:
        with self._lock:
            self.spans.append(rec)

    def add_collective(self, ev: CollectiveEvent) -> None:
        with self._lock:
            self.collectives.append(ev)

    def add_instant(self, name: str, **args) -> None:
        with self._lock:
            self.instants.append(
                (name, _now_us(), threading.get_ident(), args))

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.collectives.clear()
            self.instants.clear()

    def span_counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for s in self.spans:
                out[s.name] = out.get(s.name, 0) + 1
            return out


_RECORDER = Recorder()
_TLS = threading.local()


def get_recorder() -> Recorder:
    """The process-global recorder (one per process, like the metrics
    registry -- exporters read it, ``reset()`` clears it)."""
    return _RECORDER


def reset() -> None:
    """Clear all recorded spans/collectives/instants (counters live in
    ``repro.obs.metrics`` and have their own reset)."""
    _RECORDER.clear()


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def current_tags() -> Dict[str, Any]:
    """Merged args of the active span stack on this thread (innermost
    wins) -- how the collective seam learns the executing strategy."""
    tags: Dict[str, Any] = {}
    for _, _, args in _stack():
        tags.update(args)
    return tags


class _Span:
    """Active span handle; re-entrant per ``with`` (one handle per enter)."""

    __slots__ = ("name", "args")

    def __init__(self, name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args

    def __enter__(self):
        _stack().append((self.name, _now_us(), self.args))
        return self

    def __exit__(self, *exc):
        name, t0, args = _stack().pop()
        _RECORDER.add_span(SpanRecord(
            name=name, ts_us=t0, dur_us=_now_us() - t0,
            tid=threading.get_ident(), depth=len(_stack()), args=args))
        return False


class _NoopSpan:
    """Shared disabled-mode singleton: enter/exit allocate nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


def span(name: str, **args):
    """Context manager recording one hierarchical span.

        with obs.span("plan.build", strategy="cannon"):
            ...

    Args become the span's Perfetto ``args`` and are inherited as ambient
    tags by everything recorded inside (see ``current_tags``).  When
    recording is disabled this returns a shared no-op singleton.
    """
    if not _ENABLED:
        return NOOP_SPAN
    return _Span(name, args)


def record_collective(kind: str, group: int, shard_words: int,
                      perm=None) -> None:
    """Record one collective at the dist seam (no-op when disabled).
    ``perm`` is canonicalized; the executing strategy and the
    exposed/hidden classification (``comm="hidden"`` inside the
    double-buffered bodies' prefetch spans) are read off the ambient span
    tags."""
    if not _ENABLED:
        return
    tags = current_tags()
    _RECORDER.add_collective(CollectiveEvent(
        kind=kind, group=int(group), shard_words=int(shard_words),
        perm=canonical_perm(perm) if perm is not None else None,
        strategy=str(tags.get("strategy", "")),
        comm=str(tags.get("comm", "exposed")),
        ts_us=_now_us(), tid=threading.get_ident()))


def instant(name: str, **args) -> None:
    """Record a point annotation (no-op when disabled)."""
    if not _ENABLED:
        return
    _RECORDER.add_instant(name, **args)


@contextlib.contextmanager
def observe(fresh: bool = True):
    """Scoped recording: enable, (optionally) reset the recorder, yield it,
    then restore the previous enabled state.  The idiom for tests, the
    drift check, and ``benchmarks/run.py``:

        with obs.observe() as rec:
            execute_plan(plan, a, b)
        counts = collective_multiset(rec)
    """
    global _ENABLED
    prev = _ENABLED
    if fresh:
        _RECORDER.clear()
    _ENABLED = True
    try:
        yield _RECORDER
    finally:
        _ENABLED = prev
