"""repro.obs -- zero-dependency observability: spans, metrics, profiles.

The measured leg of the repo's measured-vs-analytic loop.  ``repro.verify``
(PR 3) proves the lowered programs move exactly the analytic number of
words; this package measures what the machine actually does with them:

  runtime    -- hierarchical span tracing (``span("plan.build")``),
                context-propagated tags, a process-global recorder, and a
                no-op fast path when disabled (the default)
  metrics    -- typed counters/histograms (plan-cache hits, per-strategy
                collective counts/bytes, kernel wall-time)
  export     -- Chrome/Perfetto ``trace_event`` JSON + the flat metrics
                JSON ``benchmarks/run.py --report`` consumes;
                ``collective_multiset`` is bitwise-comparable to the
                ``repro.verify`` interceptor's records
  profile    -- versioned :class:`MachineProfile` (fitted α–β per link
                class + measured peak FLOPs); ``build_plan(profile=...)``
                ranks strategies with calibrated seconds while the word
                counts stay analytic
  calibrate  -- ``probe_links(mesh)``: the microbenchmark pass that fits
                a profile (re-exported as ``repro.launch.perf_probe``'s
                library entry point)

Nothing here imports jax at module scope; enabling tracing costs one
module-global check per instrumentation site when off.
"""
from . import calibrate, export, metrics, profile, runtime
from .calibrate import probe_links
from .export import (SCHEMA_VERSION, collective_multiset, collective_totals,
                     metrics_snapshot, to_trace_events, write_metrics,
                     write_trace)
from .metrics import (Counter, Histogram, counter, histogram, reset_metrics,
                      snapshot)
from .profile import (PROFILE_SCHEMA, LinkParams, MachineProfile,
                      default_profile, fit_alpha_beta, load_profile,
                      save_profile)
from .runtime import (NOOP_SPAN, CollectiveEvent, Recorder, SpanRecord,
                      current_tags, disable, enable, enabled, get_recorder,
                      instant, observe, record_collective, reset, span)

__all__ = [
    "calibrate", "export", "metrics", "profile", "runtime",
    # runtime
    "enable", "disable", "enabled", "observe", "span", "instant",
    "record_collective", "current_tags", "get_recorder", "reset",
    "Recorder", "SpanRecord", "CollectiveEvent", "NOOP_SPAN",
    # metrics
    "Counter", "Histogram", "counter", "histogram", "reset_metrics",
    "snapshot",
    # export
    "SCHEMA_VERSION", "to_trace_events", "write_trace", "metrics_snapshot",
    "write_metrics", "collective_multiset", "collective_totals",
    # profile + calibration
    "PROFILE_SCHEMA", "LinkParams", "MachineProfile", "default_profile",
    "fit_alpha_beta", "load_profile", "save_profile", "probe_links",
]
