"""Exporters: Chrome/Perfetto ``trace_event`` JSON and flat metrics JSON.

``to_trace_events`` renders the recorder into the trace-event format both
``chrome://tracing`` and https://ui.perfetto.dev load directly: spans as
complete ("X") events, collectives and instants as thread-scoped instant
("i") events.  ``metrics_snapshot`` merges the metrics registry with
per-strategy collective totals into one flat dict, versioned with
``SCHEMA_VERSION`` so downstream readers (``benchmarks/run.py --report``,
the CI drift job) can evolve safely.
"""
from __future__ import annotations

import json
import os
from collections import Counter as _Counter
from typing import Any, Dict, Optional

from . import metrics as _metrics
from .runtime import Recorder, get_recorder

SCHEMA_VERSION = 1
_PID = os.getpid()


def to_trace_events(recorder: Optional[Recorder] = None) -> Dict[str, Any]:
    """Render ``recorder`` (default: the global one) as a Perfetto-loadable
    trace_event JSON object."""
    rec = recorder if recorder is not None else get_recorder()
    events = []
    for s in rec.spans:
        events.append({
            "name": s.name, "cat": "obs", "ph": "X",
            "ts": s.ts_us, "dur": s.dur_us,
            "pid": _PID, "tid": s.tid,
            "args": {k: _jsonable(v) for k, v in s.args.items()},
        })
    for ev in rec.collectives:
        events.append({
            "name": f"collective.{ev.kind}", "cat": "collective", "ph": "i",
            "ts": ev.ts_us, "pid": _PID, "tid": ev.tid, "s": "t",
            "args": {
                "strategy": ev.strategy, "group": ev.group,
                "shard_words": ev.shard_words,
                "perm_pairs": len(ev.perm) if ev.perm is not None else None,
                "comm": ev.comm,
            },
        })
    for name, ts, tid, args in rec.instants:
        events.append({
            "name": name, "cat": "obs", "ph": "i", "ts": ts,
            "pid": _PID, "tid": tid, "s": "t",
            "args": {k: _jsonable(v) for k, v in args.items()},
        })
    events.sort(key=lambda e: e["ts"])
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"schema": SCHEMA_VERSION, "producer": "repro.obs"},
    }


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def write_trace(path: str, recorder: Optional[Recorder] = None) -> str:
    """Write the trace_event JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(to_trace_events(recorder), f, indent=1)
    return path


def collective_multiset(recorder: Optional[Recorder] = None,
                        strategy: Optional[str] = None) -> _Counter:
    """Multiset of collective keys ``(kind, group, shard_words, perm)`` --
    the exact comparison form of ``repro.verify`` (``CollectiveRecord.key``
    / ``compare_records``).  ``strategy`` filters on the ambient tag."""
    rec = recorder if recorder is not None else get_recorder()
    return _Counter(ev.key for ev in rec.collectives
                    if strategy is None or ev.strategy == strategy)


def collective_totals(recorder: Optional[Recorder] = None) -> Dict[str, Dict]:
    """Per-strategy per-kind collective counts and shard words.

    ``hidden_words`` counts the subset issued as double-buffer prefetches
    (``comm == "hidden"``); ``shard_words - hidden_words`` is the exposed
    communication the overlap could not hide."""
    rec = recorder if recorder is not None else get_recorder()
    out: Dict[str, Dict] = {}
    for ev in rec.collectives:
        strat = out.setdefault(ev.strategy or "(untagged)", {})
        kind = strat.setdefault(ev.kind, {"count": 0, "shard_words": 0,
                                          "hidden_words": 0})
        kind["count"] += 1
        kind["shard_words"] += ev.shard_words
        if ev.comm == "hidden":
            kind["hidden_words"] += ev.shard_words
    return out


def metrics_snapshot(recorder: Optional[Recorder] = None) -> Dict[str, Any]:
    """The flat metrics JSON: registry snapshot + span counts + collective
    totals, under one schema-versioned envelope."""
    rec = recorder if recorder is not None else get_recorder()
    return {
        "schema": SCHEMA_VERSION,
        "metrics": _metrics.snapshot(),
        "spans": rec.span_counts(),
        "collectives": collective_totals(rec),
    }


def write_metrics(path: str, recorder: Optional[Recorder] = None) -> str:
    """Write the flat metrics JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(metrics_snapshot(recorder), f, indent=1, sort_keys=True)
    return path
