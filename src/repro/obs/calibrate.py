"""Calibration pass: microbenchmark the machine's links, fit α–β, and
return a versioned :class:`~repro.obs.profile.MachineProfile`.

``probe_links(mesh)`` is the library entry point
(``repro.launch.perf_probe`` re-exports it and adds the ``__main__`` that
writes the profile JSON the planner consumes):

  * per mesh axis, a ring ``ppermute`` of increasing shard sizes is timed
    (compile excluded, best-of-``reps``) and α–β fitted per axis; a pooled
    fit over every axis becomes the ``"ici"`` link class the planner reads
    by default;
  * without a mesh (or on one device) a device-local copy probe stands in
    as the single ``"local"`` class, so calibration degrades gracefully on
    a laptop;
  * peak matmul FLOPs come from a jit'd square matmul timing.

jax is imported lazily inside the probes -- importing this module (or
``repro.obs``) never initializes a backend.
"""
from __future__ import annotations

import datetime
import time
from typing import Optional, Sequence, Tuple

from .profile import LinkParams, MachineProfile, fit_alpha_beta
from .runtime import span

DEFAULT_SIZES_BYTES: Tuple[int, ...] = (1 << 14, 1 << 17, 1 << 20)


def _time_best(fn, reps: int) -> float:
    """Best-of-``reps`` wall seconds of ``fn()``, compile/warmup excluded."""
    import jax

    jax.block_until_ready(fn())  # warmup: compile + first dispatch
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _probe_axis(mesh, axis: str, size_bytes: int, reps: int) -> float:
    """Seconds for one ring-neighbor ppermute of a ``size_bytes`` shard
    along ``axis`` (jit'd shard_map, timed on device)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.jax_compat import shard_map

    ax_size = int(mesh.shape[axis])
    shard_words = max(size_bytes // 4, 1)
    perm = [(i, (i + 1) % ax_size) for i in range(ax_size)]

    def body(x):
        return jax.lax.ppermute(x, axis, perm)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(axis),
                          out_specs=P(axis)))
    x = jnp.zeros((ax_size * shard_words,), jnp.float32)
    return _time_best(lambda: f(x), reps)


def _probe_local(size_bytes: int, reps: int) -> float:
    """Device-local copy probe (the no-mesh fallback link class)."""
    import jax
    import jax.numpy as jnp

    words = max(size_bytes // 4, 1)
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((words,), jnp.float32)
    return _time_best(lambda: f(x), reps)


def _probe_peak_flops(reps: int, n: int = 256) -> float:
    """Measured peak matmul FLOPs from a jit'd n³ fp32 multiply."""
    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a, b: a @ b)
    t = _time_best(lambda: f(a, b), reps)
    return 2.0 * n ** 3 / max(t, 1e-9)


def _assemble_links(axis_samples, tree_axes: Sequence[str] = ()):
    """Compose the profile's link-class table from per-axis probe samples.

    ``axis_samples`` is ``[(axis, sizes_bytes, times_s), ...]``.  Every
    measured axis keeps its own ``axis:{name}`` class; the pooled classes
    follow the machine hierarchy: non-tree axes pool into ``"ici"`` (the
    planner's default link class) and ``tree_axes`` into ``"dcn"`` (the
    inter-pod class a hierarchical plan's tree axis belongs to -- DCN
    latency/bandwidth must not be averaged into the ICI fit, or a slow
    inter-pod link would silently *improve* the pooled model).  When every
    measured axis is a tree axis, ``"ici"`` falls back to the dcn fit so
    the profile stays usable by non-hierarchical estimates."""
    tree_axes = frozenset(tree_axes)
    links = []
    ici: Tuple[list, list] = ([], [])
    dcn: Tuple[list, list] = ([], [])
    for axis, sizes, times in axis_samples:
        links.append((f"axis:{axis}", fit_alpha_beta(sizes, times)))
        sink = dcn if axis in tree_axes else ici
        sink[0].extend(sizes)
        sink[1].extend(times)
    pooled = []
    if ici[0]:
        pooled.append(("ici", fit_alpha_beta(*ici)))
    elif dcn[0]:
        pooled.append(("ici", fit_alpha_beta(*dcn)))
    if dcn[0]:
        pooled.append(("dcn", fit_alpha_beta(*dcn)))
    return pooled + links


def probe_links(mesh=None, *,
                sizes_bytes: Sequence[int] = DEFAULT_SIZES_BYTES,
                reps: int = 3,
                tree_axes: Sequence[str] = ()) -> MachineProfile:
    """Microbenchmark every link class of ``mesh`` and return the fitted
    :class:`MachineProfile` (see module docstring).  This is the
    calibration pass the ROADMAP's calibrated-cost-model item asks for;
    persist the result with ``repro.obs.save_profile`` and hand it to
    ``build_plan(profile=...)``.

    ``tree_axes`` names the mesh axes that are inter-pod (DCN-class)
    links: they are excluded from the pooled ``"ici"`` fit and pooled into
    a separate ``"dcn"`` class instead (see ``_assemble_links``), so a
    calibrated ranking can prefer the hierarchical fat-tree plan exactly
    when the inter-pod link is slow.
    """
    import jax

    with span("obs.calibrate", mesh=str(getattr(mesh, "shape", None))):
        links = []
        if mesh is not None and mesh.size > 1:
            samples = []
            for axis in mesh.axis_names:
                if int(mesh.shape[axis]) < 2:
                    continue
                times = [_probe_axis(mesh, axis, s, reps)
                         for s in sizes_bytes]
                samples.append((axis, list(sizes_bytes), times))
            links = _assemble_links(samples, tree_axes)
        if not links:
            times = [_probe_local(s, reps) for s in sizes_bytes]
            fit = fit_alpha_beta(sizes_bytes, times)
            links = [("ici", fit), ("local", fit)]
        return MachineProfile(
            platform=jax.default_backend(),
            peak_flops=_probe_peak_flops(reps),
            links=tuple(links),
            created=datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds"),
        )
