"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # attention flavour
    attn_type: str = "gqa"       # gqa | mla | none
    window: int = 0              # sliding-window size (0 = full)
    rope_theta: float = 10000.0
    attn_impl: str = "xla"       # xla (chunked masked einsum) | flash (pallas)
    attn_chunk: int = 1024       # q-chunk for the xla impl

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # deepseek-moe: leading dense layers
    moe_group_size: int = 256    # GShard routing-group size

    # SSM / hybrid / xlstm
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    block_pattern: Tuple[str, ...] = ()   # e.g. ("m","m","m","s") per group
    shared_attn_every: int = 0            # zamba2: shared attn period

    # encoder-decoder (seamless)
    enc_layers: int = 0
    dec_layers: int = 0

    # numerics / memory
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "none"          # none | full | dots
    tie_embeddings: bool = False
    attn_probs_dtype: str = "fp32"   # fp32 | bf16: P matrix of softmax(QK)V
    gate_dtype: str = "fp32"         # fp32 | bf16: SSD/mLSTM decay matrices

    # distribution
    matmul_strategy: str = "xla"  # xla | auto | ring_ag | ring_rs

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def group_size(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Total parameters (exact for the implemented modules)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += d * v  # lm head
        n += d  # final norm
        per_layer = self._per_layer_params()
        n += per_layer
        if self.family == "audio":
            pass  # enc/dec accounted inside _per_layer_params
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        expert = 3 * d * self.moe_d_ff
        inactive = (self.num_experts - self.top_k) * expert
        moe_layers = self.num_layers - self.first_dense_layers
        return self.param_count() - inactive * moe_layers

    def _attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        if self.attn_type == "mla":
            qr, kvr = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vh = self.qk_nope_dim, self.qk_rope_dim, self.v_head_dim
            n = d * qr + qr * h * (nope + rope)           # q down+up
            n += d * (kvr + rope)                          # kv down (+ shared rope k)
            n += kvr * h * (nope + vh)                     # kv up
            n += h * vh * d                                # o proj
            n += qr + kvr                                  # lora norms
            return n
        return d * h * hd + 2 * d * kv * hd + h * hd * d  # q, k, v, o

    def _mlp_params(self, ff: int) -> int:
        return 3 * self.d_model * ff

    def _per_layer_params(self) -> int:
        d = self.d_model
        if self.family in ("dense", "vlm"):
            per = self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
            return per * self.num_layers
        if self.family == "moe":
            expert = 3 * d * self.moe_d_ff
            moe = (self.num_experts + self.num_shared_experts) * expert
            moe += d * self.num_experts  # router
            per_moe = self._attn_params() + moe + 2 * d
            per_dense = self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
            nd = self.first_dense_layers
            return per_dense * nd + per_moe * (self.num_layers - nd)
        if self.family == "audio":
            enc = (self._attn_params() + self._mlp_params(self.d_ff) + 2 * d)
            dec = (2 * self._attn_params() + self._mlp_params(self.d_ff) + 3 * d)
            return enc * self.enc_layers + dec * self.dec_layers
        if self.family == "ssm":  # xlstm: mLSTM + sLSTM mix
            # approximation using the mLSTM block shape for both
            hd = d // self.num_heads
            m = 3 * d * d + d * d + self._mlp_params(self.d_ff) if self.d_ff else 4 * d * d + 2 * d
            return m * self.num_layers
        if self.family == "hybrid":  # zamba2: mamba-only blocks + one shared
            din = self.ssm_expand * d
            nheads = din // self.ssm_headdim
            conv_ch = din + 2 * self.ssm_state
            mamba = (d * (2 * din + 2 * self.ssm_state + nheads)  # in_proj
                     + conv_ch * self.conv_kernel + conv_ch       # conv w+b
                     + 3 * nheads                                  # A, D, dt_bias
                     + din * d + din)                              # out_proj, norm
            per = mamba + d  # + block norm; no per-layer MLP in zamba blocks
            total = per * self.num_layers
            if self.shared_attn_every:
                total += (self._attn_params() + self._mlp_params(self.d_ff)
                          + 2 * d                # shared block norms
                          + 2 * d * d)           # concat down-projection
            return total
        raise ValueError(self.family)
