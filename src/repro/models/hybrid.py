"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

54 Mamba layers grouped in blocks of ``shared_attn_every``; after each group
the single shared transformer block (same parameters every invocation, as in
Zamba/Zamba2) runs on concat(hidden, original_embedding) projected back to
d_model.  Each invocation keeps its own KV cache (params shared, state not).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.layers.attention import gqa_attention, gqa_cache, gqa_params
from repro.layers.blocks import block_apply, block_params
from repro.layers.embed import embed, embed_params, unembed
from repro.layers.linear import linear, linear_params
from repro.layers.mamba2 import mamba2_cache
from repro.layers.mlp import mlp, mlp_params
from repro.layers.norms import rms_norm, rms_norm_params
from repro.models.config import ModelConfig
from repro.models.lm import _remat, _stack_init, cross_entropy
from repro.runtime.sharding import constrain

Params = Dict
Cache = Dict


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        assert cfg.shared_attn_every > 0
        assert cfg.num_layers % cfg.shared_attn_every == 0
        self.n_groups = cfg.num_layers // cfg.shared_attn_every

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, km, ks, kc, kf = jax.random.split(key, 5)
        return {
            "embed": embed_params(
                ke, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings, self.dtype
            ),
            # (G, per_group, ...) doubly-stacked mamba blocks
            "mamba_layers": _stack_init(
                km, cfg.num_layers,
                lambda k: block_params(k, cfg, "mamba", self.dtype),
            ),
            "shared_in": linear_params(kc, 2 * cfg.d_model, cfg.d_model, self.dtype),
            "shared": {
                "attn_norm": rms_norm_params(cfg.d_model),
                "attn": gqa_params(ks, cfg, self.dtype),
                "mlp_norm": rms_norm_params(cfg.d_model),
                "mlp": mlp_params(kf, cfg.d_model, cfg.d_ff, self.dtype),
            },
            "final_norm": rms_norm_params(cfg.d_model),
        }

    def _regroup(self, stacked):
        g, per = self.n_groups, self.cfg.shared_attn_every
        return jax.tree.map(
            lambda a: a.reshape(g, per, *a.shape[1:]), stacked
        )

    def _shared_block(self, params, x, x0, positions, cache=None, pos=None):
        cfg = self.cfg
        h = linear(jnp.concatenate([x, x0], axis=-1), params["shared_in"])
        sp = params["shared"]
        hn = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
        a, new_cache = gqa_attention(sp["attn"], hn, cfg, positions, cache, pos)
        h = h + a
        hn = rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
        h = h + mlp(sp["mlp"], hn)
        return x + h, new_cache

    def forward(self, params: Params, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x0 = embed(params["embed"], tokens)
        x0 = constrain(x0, "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        grouped = self._regroup(params["mamba_layers"])

        def group_body(x, group_params):
            def mamba_body(x, lp):
                x, _, _ = block_apply(lp, x, cfg, "mamba", positions)
                return x, None
            x, _ = jax.lax.scan(_remat(mamba_body, cfg), x, group_params)
            x, _ = self._shared_block(params, x, x0, positions)
            return x, None

        x, _ = jax.lax.scan(group_body, x0, grouped)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)
        return constrain(logits, "batch", None, "model"), jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, _ = self.forward(params, batch["tokens"])
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce}

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Cache:
        cfg = self.cfg
        m_one = mamba2_cache(cfg, batch, self.dtype)
        a_one = gqa_cache(cfg, batch, max_seq, self.dtype)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), m_one
            ),
            "shared": jax.tree.map(
                lambda a: jnp.zeros((self.n_groups,) + a.shape, a.dtype), a_one
            ),
        }

    def decode_step(self, params, cache: Cache, tokens, pos) -> Tuple[jax.Array, Cache]:
        cfg = self.cfg
        x0 = embed(params["embed"], tokens)
        positions = jnp.full((1,), pos, jnp.int32)
        grouped_p = self._regroup(params["mamba_layers"])
        grouped_c = self._regroup_cache(cache["mamba"])

        def group_body(x, args):
            gp, gc, sc = args
            def mamba_body(x, lp_lc):
                lp, lc = lp_lc
                x, _, nc = block_apply(lp, x, cfg, "mamba", positions, lc, pos)
                return x, nc
            x, new_gc = jax.lax.scan(mamba_body, x, (gp, gc))
            x, new_sc = self._shared_block(params, x, x0, positions, sc, pos)
            return x, (new_gc, new_sc)

        x, (new_mamba, new_shared) = jax.lax.scan(
            group_body, x0, (grouped_p, grouped_c, cache["shared"])
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)[:, 0]
        new_cache = {
            "mamba": jax.tree.map(
                lambda a: a.reshape(cfg.num_layers, *a.shape[2:]), new_mamba
            ),
            "shared": new_shared,
        }
        return logits, new_cache

    def _regroup_cache(self, stacked):
        g, per = self.n_groups, self.cfg.shared_attn_every
        return jax.tree.map(lambda a: a.reshape(g, per, *a.shape[1:]), stacked)
