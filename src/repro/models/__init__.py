from repro.models.config import ModelConfig
from repro.models.registry import build_model

__all__ = ["ModelConfig", "build_model"]
