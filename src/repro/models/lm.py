"""Decoder-only LM covering the dense / MoE / VLM (early-fusion) families.

Layers are *stacked* (one leading L axis per parameter) and executed with
``jax.lax.scan`` so the HLO -- and hence the 512-device dry-run compile time
-- is depth-independent.  deepseek-moe's leading dense layers live in their
own (short) stack.  Decode threads the per-layer KV caches through the same
scan.  Remat policy is configurable per config (none | dots | full).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.blocks import block_apply, block_params
from repro.layers.attention import gqa_cache, mla_cache
from repro.layers.embed import embed, embed_params, unembed
from repro.layers.norms import rms_norm, rms_norm_params
from repro.models.config import ModelConfig
from repro.runtime.sharding import constrain

Params = Dict
Cache = Dict


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def _stack_init(key, n: int, mk):
    return jax.vmap(mk)(jax.random.split(key, n))


class DecoderLM:
    # serving can hand this model left-padded batches with per-row position
    # offsets (see ``prefill``/``decode_step``); the recurrent families
    # cannot (their state carries pad tokens forward), so the serving
    # runtime checks this flag before passing offsets.
    supports_position_offsets = True

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kind = "attn_moe" if cfg.num_experts else "attn_mlp"
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # -- params -------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        k_e, k_d, k_l = jax.random.split(key, 3)
        params: Params = {
            "embed": embed_params(
                k_e, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings, self.dtype
            ),
            "final_norm": rms_norm_params(cfg.d_model),
        }
        nd = cfg.first_dense_layers
        if nd:
            params["dense_layers"] = _stack_init(
                k_d, nd, lambda k: block_params(k, cfg, "attn_mlp", self.dtype)
            )
        params["layers"] = _stack_init(
            k_l, cfg.num_layers - nd,
            lambda k: block_params(k, cfg, self.kind, self.dtype),
        )
        return params

    # -- forward ------------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """tokens: (B, S) -> (logits (B, S, V) fp32, aux loss)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        x = constrain(x, "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        aux0 = jnp.zeros((), jnp.float32)

        if cfg.first_dense_layers:
            def dense_body(carry, lp):
                x, aux = carry
                x, a, _ = block_apply(lp, x, cfg, "attn_mlp", positions)
                return (x, aux + a), None
            (x, aux0), _ = jax.lax.scan(
                _remat(dense_body, cfg), (x, aux0), params["dense_layers"]
            )

        def body(carry, lp):
            x, aux = carry
            x, a, _ = block_apply(lp, x, cfg, self.kind, positions)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, aux0), params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)
        logits = constrain(logits, "batch", None, "model")
        return logits, aux

    def loss(self, params: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, aux = self.forward(params, batch["tokens"])
        ce = cross_entropy(logits, batch["labels"])
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Cache:
        cfg = self.cfg
        mk = mla_cache if cfg.attn_type == "mla" else gqa_cache
        one = mk(cfg, batch, max_seq, self.dtype)
        nd = cfg.first_dense_layers
        cache: Cache = {
            "layers": jax.tree.map(
                lambda a: jnp.zeros((cfg.num_layers - nd,) + a.shape, a.dtype), one
            )
        }
        if nd:
            cache["dense_layers"] = jax.tree.map(
                lambda a: jnp.zeros((nd,) + a.shape, a.dtype), one
            )
        return cache

    def prefill(
        self, params: Params, cache: Cache, tokens: jax.Array,
        offsets: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Cache]:
        """One-pass prompt ingestion: runs the full (B, S_prompt) forward
        through the *cached* attention path (writes K/V at positions
        [0, S)), returning last-token logits + the filled cache.  The
        production serving path: prompt cost is one forward instead of
        S_prompt decode steps.

        ``offsets`` (B,) marks per-row left-padding: row i's logical token
        positions become arange(S) - offsets[i], so its padding slots sit
        at negative positions and attention masks them out -- a prompt
        left-padded into a bucket decodes exactly as it would alone."""
        positions = jnp.arange(tokens.shape[1])
        if offsets is not None:
            positions = positions[None, :] - offsets[:, None]
        return self._cached_forward(params, cache, tokens,
                                    positions, jnp.int32(0), offsets)

    def decode_step(
        self, params: Params, cache: Cache, tokens: jax.Array, pos: jax.Array,
        offsets: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Cache]:
        """tokens: (B, 1); pos: scalar int32 (the absolute cache slot).
        Returns (logits (B, V), cache).  ``offsets`` as in ``prefill``:
        row i's logical query position is pos - offsets[i]."""
        if offsets is not None:
            positions = pos - offsets[:, None]  # (B, 1) logical positions
        else:
            positions = jnp.full((1,), pos, jnp.int32)
        return self._cached_forward(params, cache, tokens, positions, pos,
                                    offsets)

    def _cached_forward(
        self, params: Params, cache: Cache, tokens: jax.Array,
        positions: jax.Array, pos: jax.Array,
        offsets: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Cache]:
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        new_cache: Cache = {}

        if cfg.first_dense_layers:
            def dense_body(x, lp_lc):
                lp, lc = lp_lc
                x, _, nc = block_apply(lp, x, cfg, "attn_mlp", positions, lc,
                                       pos, offsets)
                return x, nc
            x, new_cache["dense_layers"] = jax.lax.scan(
                dense_body, x, (params["dense_layers"], cache["dense_layers"])
            )

        def body(x, lp_lc):
            lp, lc = lp_lc
            x, _, nc = block_apply(lp, x, cfg, self.kind, positions, lc, pos,
                                   offsets)
            return x, nc

        x, new_cache["layers"] = jax.lax.scan(
            body, x, (params["layers"], cache["layers"])
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)[:, -1]
        return logits, new_cache


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits fp32 (B, S, V); labels (B, S) with -100 = ignore."""
    valid = labels >= 0
    labels_c = jnp.clip(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.clip(jnp.sum(valid), 1)
