"""Model registry: ModelConfig -> model instance."""
from __future__ import annotations

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.lm import DecoderLM
from repro.models.xlstm_model import XLSTMLM


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
