"""xLSTM LM: repeating groups of (mLSTM x k, sLSTM x 1) blocks.

The group pattern comes from cfg.block_pattern (default mmm-s); groups are
scanned (stacked params per block kind within the group), so depth stays out
of the HLO.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.layers.blocks import block_apply, block_params
from repro.layers.embed import embed, embed_params, unembed
from repro.layers.norms import rms_norm, rms_norm_params
from repro.layers.xlstm import mlstm_cache, slstm_cache
from repro.models.config import ModelConfig
from repro.models.lm import _remat, _stack_init, cross_entropy
from repro.runtime.sharding import constrain

Params = Dict
Cache = Dict


class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        pattern = cfg.block_pattern or ("mlstm", "mlstm", "mlstm", "slstm")
        assert cfg.num_layers % len(pattern) == 0
        self.pattern = pattern
        self.n_groups = cfg.num_layers // len(pattern)
        self.n_m = sum(1 for b in pattern if b == "mlstm")
        self.n_s = sum(1 for b in pattern if b == "slstm")

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, km, ks = jax.random.split(key, 3)
        params: Params = {
            "embed": embed_params(
                ke, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings, self.dtype
            ),
            "final_norm": rms_norm_params(cfg.d_model),
        }
        if self.n_m:
            params["m_layers"] = _stack_init(
                km, self.n_groups * self.n_m,
                lambda k: block_params(k, cfg, "mlstm", self.dtype),
            )
        if self.n_s:
            params["s_layers"] = _stack_init(
                ks, self.n_groups * self.n_s,
                lambda k: block_params(k, cfg, "slstm", self.dtype),
            )
        return params

    def _grouped(self, params, name, n_per):
        return jax.tree.map(
            lambda a: a.reshape(self.n_groups, n_per, *a.shape[1:]), params[name]
        )

    def forward(self, params: Params, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        x = constrain(x, "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        gm = self._grouped(params, "m_layers", self.n_m)
        gs = self._grouped(params, "s_layers", self.n_s)

        def group_body(x, gp):
            mp, sp = gp
            def m_body(x, lp):
                x, _, _ = block_apply(lp, x, cfg, "mlstm", positions)
                return x, None
            x, _ = jax.lax.scan(_remat(m_body, cfg), x, mp)
            def s_body(x, lp):
                x, _, _ = block_apply(lp, x, cfg, "slstm", positions)
                return x, None
            x, _ = jax.lax.scan(_remat(s_body, cfg), x, sp)
            return x, None

        x, _ = jax.lax.scan(group_body, x, (gm, gs))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)
        return constrain(logits, "batch", None, "model"), jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, _ = self.forward(params, batch["tokens"])
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce}

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Cache:
        cfg = self.cfg
        m_one = mlstm_cache(cfg, batch)
        s_one = slstm_cache(cfg, batch)
        return {
            "m": jax.tree.map(
                lambda a: jnp.zeros((self.n_groups * self.n_m,) + a.shape, a.dtype),
                m_one,
            ),
            "s": jax.tree.map(
                lambda a: jnp.zeros((self.n_groups * self.n_s,) + a.shape, a.dtype),
                s_one,
            ),
        }

    def decode_step(self, params, cache: Cache, tokens, pos) -> Tuple[jax.Array, Cache]:
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        positions = jnp.full((1,), pos, jnp.int32)
        gm = self._grouped(params, "m_layers", self.n_m)
        gs = self._grouped(params, "s_layers", self.n_s)
        cm = jax.tree.map(
            lambda a: a.reshape(self.n_groups, self.n_m, *a.shape[1:]), cache["m"]
        )
        cs = jax.tree.map(
            lambda a: a.reshape(self.n_groups, self.n_s, *a.shape[1:]), cache["s"]
        )

        def group_body(x, args):
            mp, sp, mc, sc = args
            def m_body(x, lp_lc):
                lp, lc = lp_lc
                x, _, nc = block_apply(lp, x, cfg, "mlstm", positions, lc, pos)
                return x, nc
            x, new_mc = jax.lax.scan(m_body, x, (mp, mc))
            def s_body(x, lp_lc):
                lp, lc = lp_lc
                x, _, nc = block_apply(lp, x, cfg, "slstm", positions, lc, pos)
                return x, nc
            x, new_sc = jax.lax.scan(s_body, x, (sp, sc))
            return x, (new_mc, new_sc)

        x, (ncm, ncs) = jax.lax.scan(group_body, x, (gm, gs, cm, cs))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)[:, 0]
        new_cache = {
            "m": jax.tree.map(
                lambda a: a.reshape(self.n_groups * self.n_m, *a.shape[2:]), ncm
            ),
            "s": jax.tree.map(
                lambda a: a.reshape(self.n_groups * self.n_s, *a.shape[2:]), ncs
            ),
        }
        return logits, new_cache
