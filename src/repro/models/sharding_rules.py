"""PartitionSpec rules for parameters, optimizer state and caches.

Rules are name-based (the layer library uses a stable naming convention) and
rank-relative: stacked-layer leading axes get ``None`` prepended
automatically, so the same table covers per-layer and scanned parameters.

Weight sharding follows the standard Megatron mapping onto the ``model``
axis -- column-parallel up-projections, row-parallel down-projections,
vocab-sharded embedding, expert-parallel MoE stacks -- which is exactly the
1-D torus solution family of the paper's equations (see repro.dist.ring).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.sharding import (MODEL_AXIS, planned_matmul_axes,
                                    resolve_axis)

# name -> (base_rank, base_spec over logical axes)
_RULES: Dict[str, Tuple[int, Tuple]] = {
    # embeddings
    "embedding": (2, ("model", None)),
    "lm_head": (2, (None, "model")),
    # attention / generic projections (column-parallel)
    "wq": (2, (None, "model")),
    "wk": (2, (None, "model")),
    "wv": (2, (None, "model")),
    "wq_a": (2, (None, "model")),
    "wq_b": (2, (None, "model")),
    "wkv_a": (2, (None, "model")),
    "wkv_b": (2, (None, "model")),
    "w_in": (2, (None, "model")),
    "w_gates": (2, (None, "model")),
    "in_proj": (2, (None, "model")),
    "shared_in": (2, (None, "model")),
    # row-parallel
    "wo": (2, ("model", None)),
    "w_down": (2, ("model", None)),
    "out_proj": (2, ("model", None)),
    # dense mlp column-parallel
    "w_gate": (2, (None, "model")),
    "w_up": (2, (None, "model")),
    # moe expert stacks (expert-parallel) -- matched with parent 'moe'
    "moe/w_gate": (3, ("model", None, None)),
    "moe/w_up": (3, ("model", None, None)),
    "moe/w_down": (3, ("model", None, None)),
    "router": (2, (None, None)),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
    return tuple(names)


def _spec_for(path, leaf) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    # expert stacks sit directly under "moe"; the shared expert is a plain
    # MLP nested at moe/shared/* and must use the dense rules
    key = f"moe/{name}" if parent == "moe" and f"moe/{name}" in _RULES else name
    if key not in _RULES:
        return P()  # replicated (norms, biases, A_log, conv, r, ...)
    base_rank, base = _RULES[key]
    extra = leaf.ndim - base_rank
    if extra < 0:
        return P()
    return P(*((None,) * extra + base))


def param_specs(params: Any) -> Any:
    """Pytree of PartitionSpec mirroring ``params``."""
    return jax.tree_util.tree_map_with_path(_spec_for, params)


# weights below this size are cheaper replicated than collectived over
_AUTO_MIN_DIM = 128


def ranked_linear_spec(shape, mesh: Mesh, *, tokens: int = 8192) -> P:
    """Estimate-ranked spec for a 2-D weight not covered by ``_RULES``:
    prices column- vs row-parallel with the plan cost model (see
    ``repro.runtime.sharding.planned_matmul_axes``) instead of assuming a
    name convention.  Falls back to replicated for weights too small to be
    worth a collective or not divisible by the model axis."""
    if len(shape) != 2 or min(shape) < _AUTO_MIN_DIM:
        return P()
    model = mesh.shape.get(MODEL_AXIS, 1)
    if model <= 1:
        return P()
    axes = planned_matmul_axes(shape[0], shape[1], mesh=mesh, tokens=tokens)
    axes = tuple(
        a if a is not None and shape[i] % model == 0 else None
        for i, a in enumerate(axes)
    )
    return P(*axes)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= _axis_size(mesh, a)
        return out
    return mesh.shape.get(axis, 1)


def param_shardings(params: Any, mesh: Mesh, *,
                    auto_matmul: bool = False) -> Any:
    """Resolve logical specs against ``mesh``, dropping any sharded axis a
    dimension cannot honour (e.g. tiny gate projections vs model=16).

    ``auto_matmul=True`` additionally consults the plan cost model for 2-D
    weights the name table leaves replicated (``ranked_linear_spec``), so
    new layer families get a Megatron-style split derived from word counts
    rather than silently paying replication."""

    def resolve(leaf, spec: P) -> NamedSharding:
        shape = getattr(leaf, "shape", ())
        if auto_matmul and tuple(spec) == () and len(shape) == 2:
            spec = ranked_linear_spec(shape, mesh)
        axes = [resolve_axis(a, mesh) for a in spec]
        for i, a in enumerate(axes):
            if a is None or i >= len(shape):
                continue
            if shape[i] % _axis_size(mesh, a) != 0:
                axes[i] = None
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(resolve, params, param_specs(params))


# decode-cache layout: every cache tensor is (L, B, ...); per name the
# candidate axes to shard over 'model', in priority order (first divisible
# dimension wins).  KV caches prefer heads, then the SEQUENCE axis:
# seq-sharding is split-KV (flash-decoding) -- the paper's contraction-axis
# parallelism (2.5D j-split) applied to decode.  Sharding head_dim instead
# was measured to force a full-cache all-gather (9.2 GB/step on
# llama decode_32k) because queries arrive head-sharded; see
# EXPERIMENTS.md Sec. Perf, hillclimb C.
_CACHE_MODEL_DIMS = {
    "k": (3, 2),        # (L, B, S, H_kv, Dh): heads, else seq (split-KV)
    "v": (3, 2),
    "c_kv": (2,),       # (L, B, S, R): seq (split-KV in the latent space)
    "k_rope": (2,),
    "ssm": (2,),        # (L, B, H, P, N): heads
    "conv": (3,),       # (L, B, K, C): channels
    "C": (2, 3),        # mLSTM state (L, B, H, D, D)
    "n": (2,),
    "h": (2,),
    "c": (2,),
}
_CACHE_SEQ_DIM = {"k": 2, "v": 2, "c_kv": 2, "k_rope": 2}


def cache_specs(cache: Any, *, shard_batch: bool,
                model_size: int = 1, data_size: int = 1) -> Any:
    """Decode-cache specs.

    shard_batch=True (decode_32k): batch over ('pod','data') AND the first
    divisible head/feature dim over 'model' -- KV caches are the dominant
    decode state and must use the whole mesh.
    shard_batch=False (long_500k, batch=1): KV sequence over 'data'
    (split-KV decode) plus the same model-axis dim."""

    def spec(path, leaf) -> P:
        names = _path_names(path)
        name = names[-1]
        shape = getattr(leaf, "shape", ())
        n = len(shape)
        axes = [None] * n
        if shard_batch:
            if n >= 2 and shape[1] % max(data_size, 1) == 0:
                axes[1] = "batch"
        else:
            sd = _CACHE_SEQ_DIM.get(name)
            if sd is not None and sd < n and shape[sd] % max(data_size, 1) == 0:
                axes[sd] = "data"
        for dim in _CACHE_MODEL_DIMS.get(name, ()):
            if dim < n and axes[dim] is None and model_size > 1 \
                    and shape[dim] % model_size == 0:
                axes[dim] = "model"
                break
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache)


def cache_shardings(cache: Any, mesh: Mesh, *, shard_batch: bool) -> Any:
    model_size = mesh.shape.get("model", 1)
    data_size = _axis_size(mesh, resolve_axis("batch", mesh))
    specs = cache_specs(
        cache, shard_batch=shard_batch,
        model_size=model_size,
        data_size=data_size if shard_batch else mesh.shape.get("data", 1),
    )

    def resolve(leaf, spec: P) -> NamedSharding:
        axes = [resolve_axis(a, mesh) for a in spec]
        shape = getattr(leaf, "shape", ())
        for i, a in enumerate(axes):
            if a is None or i >= len(shape):
                continue
            if shape[i] % _axis_size(mesh, a) != 0:
                axes[i] = None
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(resolve, cache, specs)


def zero_shardings(params: Any, mesh: Mesh) -> Any:
    """ZeRO-1 shardings for fp32 optimizer state (master/m/v): the param
    spec plus the data axis on the largest still-unsharded dimension.
    Cuts per-device optimizer bytes by |data| (x16 here); GSPMD inserts the
    corresponding reduce-scatter/all-gather pair around the update."""
    data_axes = resolve_axis("batch", mesh)  # ('pod','data') when multi-pod
    dsize = _axis_size(mesh, data_axes)

    def resolve(leaf, spec: P) -> NamedSharding:
        shape = getattr(leaf, "shape", ())
        axes = [resolve_axis(a, mesh) for a in spec]
        axes += [None] * (len(shape) - len(axes))  # replicated-spec padding
        for i, a in enumerate(axes):
            if a is not None and i < len(shape) \
                    and shape[i] % _axis_size(mesh, a) != 0:
                axes[i] = None
        if dsize > 1 and len(shape) >= 1:
            cands = [i for i in range(len(shape))
                     if axes[i] is None and shape[i] % dsize == 0]
            if cands:
                best = max(cands, key=lambda i: shape[i])
                axes[best] = data_axes
        return NamedSharding(mesh, P(*axes[: len(shape)]))

    return jax.tree.map(resolve, params, param_specs(params))
