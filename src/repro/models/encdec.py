"""Encoder-decoder LM (seamless-m4t backbone).

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d) from ``input_specs``.  Encoder
blocks are non-causal self-attention + MLP; decoder blocks add causal
self-attention (cached at decode) and cross-attention over the encoder
output (K/V cached once at prefill).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.attention import (chunked_attention, gqa_attention,
                                    gqa_cache, gqa_params)
from repro.layers.embed import embed, embed_params, unembed
from repro.layers.linear import linear, linear_params
from repro.layers.mlp import mlp, mlp_params
from repro.layers.norms import rms_norm, rms_norm_params
from repro.models.config import ModelConfig
from repro.models.lm import _remat, _stack_init, cross_entropy
from repro.runtime.sharding import constrain

Params = Dict
Cache = Dict


def _xattn_params(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_params(ks[0], d, h * hd, dtype),
        "wk": linear_params(ks[1], d, kv * hd, dtype),
        "wv": linear_params(ks[2], d, kv * hd, dtype),
        "wo": linear_params(ks[3], h * hd, d, dtype),
    }


def _cross_attention(p, x, memory, cfg, cached_kv=None):
    """x: (B, St, d) queries; memory: (B, Ss, d) encoder output."""
    b, st, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(x, p["wq"]).reshape(b, st, h, hd)
    if cached_kv is None:
        ss = memory.shape[1]
        k = linear(memory, p["wk"]).reshape(b, ss, kv, hd)
        v = linear(memory, p["wv"]).reshape(b, ss, kv, hd)
    else:
        k, v = cached_kv["k"], cached_kv["v"]
        ss = k.shape[1]
    qpos = jnp.arange(st)
    kpos = jnp.arange(ss)
    o = chunked_attention(
        q, k, v, qpos, kpos, chunk=cfg.attn_chunk, causal=False
    )
    return linear(o.reshape(b, st, h * hd), p["wo"])


def _enc_block_params(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rms_norm_params(cfg.d_model),
        "attn": gqa_params(k1, cfg, dtype),
        "mlp_norm": rms_norm_params(cfg.d_model),
        "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_params(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": rms_norm_params(cfg.d_model),
        "self_attn": gqa_params(k1, cfg, dtype),
        "cross_norm": rms_norm_params(cfg.d_model),
        "cross_attn": _xattn_params(k2, cfg, dtype),
        "mlp_norm": rms_norm_params(cfg.d_model),
        "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, dtype),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, kd, kt = jax.random.split(key, 3)
        return {
            "embed": embed_params(
                kt, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings, self.dtype
            ),
            "enc_layers": _stack_init(
                ke, cfg.enc_layers, lambda k: _enc_block_params(k, cfg, self.dtype)
            ),
            "dec_layers": _stack_init(
                kd, cfg.dec_layers, lambda k: _dec_block_params(k, cfg, self.dtype)
            ),
            "enc_norm": rms_norm_params(cfg.d_model),
            "final_norm": rms_norm_params(cfg.d_model),
        }

    def encode(self, params: Params, src_embed: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = constrain(src_embed.astype(self.dtype), "batch", None, None)
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            a, _ = gqa_attention(lp["attn"], h, cfg, positions, causal=False)
            x = x + a
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            return x + mlp(lp["mlp"], h), None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def decode_train(self, params, memory, tgt_tokens) -> jax.Array:
        cfg = self.cfg
        x = embed(params["embed"], tgt_tokens)
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            h = rms_norm(x, lp["self_norm"], cfg.norm_eps)
            a, _ = gqa_attention(lp["self_attn"], h, cfg, positions, causal=True)
            x = x + a
            h = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
            x = x + _cross_attention(lp["cross_attn"], h, memory, cfg)
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            return x + mlp(lp["mlp"], h), None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(params["embed"], x, cfg.vocab_size)

    def forward(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        memory = self.encode(params, batch["src_embed"])
        logits = self.decode_train(params, memory, batch["tokens"])
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, _ = self.forward(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce}

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, src_len: int = 1024) -> Cache:
        cfg = self.cfg
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        self_one = gqa_cache(cfg, batch, max_seq, self.dtype)
        ld = cfg.dec_layers
        return {
            "self": jax.tree.map(
                lambda a: jnp.zeros((ld,) + a.shape, a.dtype), self_one
            ),
            "cross": {
                "k": jnp.zeros((ld, batch, src_len, kv, hd), self.dtype),
                "v": jnp.zeros((ld, batch, src_len, kv, hd), self.dtype),
            },
        }

    def prefill_cross(self, params, memory, cache: Cache) -> Cache:
        """Fill the cross-attention K/V cache from encoder output."""
        cfg = self.cfg
        b, ss, _ = memory.shape
        kv, hd = cfg.num_kv_heads, cfg.head_dim

        def body(_, lp):
            k = linear(memory, lp["cross_attn"]["wk"]).reshape(b, ss, kv, hd)
            v = linear(memory, lp["cross_attn"]["wv"]).reshape(b, ss, kv, hd)
            return None, {"k": k, "v": v}

        _, cross = jax.lax.scan(body, None, params["dec_layers"])
        return {**cache, "cross": cross}

    def decode_step(self, params, cache: Cache, tokens, pos) -> Tuple[jax.Array, Cache]:
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        positions = jnp.full((1,), pos, jnp.int32)

        def body(x, lp_lc):
            lp, sc, cc = lp_lc
            h = rms_norm(x, lp["self_norm"], cfg.norm_eps)
            a, nsc = gqa_attention(lp["self_attn"], h, cfg, positions, sc, pos)
            x = x + a
            h = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
            x = x + _cross_attention(lp["cross_attn"], h, None, cfg, cached_kv=cc)
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            return x + mlp(lp["mlp"], h), nsc

        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross"])
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size)[:, 0]
        return logits, {"self": new_self, "cross": cache["cross"]}
