"""Serving runtime: batched greedy/temperature decoding over the KV cache.

``generate`` drives model.decode_step with a single jit'd step (position is
a traced scalar, so one compile serves the whole generation).  Prompts are
consumed through the same step (teacher forcing) -- robust across every
model family here, including the recurrent ones whose prefill is the
recurrence itself.

Two serving-specific extensions over the seed version:

  * ``mesh=`` routes every matmul in the forward through the plan engine
    (``repro.plan.planned_matmuls``): decode executes solver-derived
    ``SchedulePlan``s -- cost-model-ranked (or pinned via ``strategy=``),
    memoized in the plan cache -- instead of the local GSPMD baseline.
  * ``lens=`` marks per-request true prompt lengths in a left-padded
    batch.  Models that support per-row position offsets
    (``supports_position_offsets``) then mask the padding slots out of
    attention and place real tokens at their logical positions, so a
    request decoded inside a bucket emits the same greedy tokens as it
    would alone (pinned by tests/test_serve.py).

``repro.serve.Server`` builds the production path on top of this module:
persistent compiled step functions, (batch, seq) bucket routing, AOT
plan-cache warmup, and latency accounting.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    max_seq: int = 256

    def __post_init__(self):
        if self.max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {self.max_new_tokens}")
        if self.max_seq <= 0:
            raise ValueError(f"max_seq must be > 0, got {self.max_seq}")
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")

    def validate_prompt_len(self, sp: int) -> None:
        """The KV/state cache holds ``max_seq`` slots; a prompt of length
        ``sp`` plus ``max_new_tokens`` generated tokens must fit or decode
        would silently wrap/overrun the cache."""
        if sp + self.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt length {sp} + max_new_tokens {self.max_new_tokens} "
                f"exceeds max_seq {self.max_seq}; raise max_seq or shorten "
                f"the request")


def generate(
    model, params, prompts: np.ndarray, cfg: ServeConfig,
    key: Optional[jax.Array] = None,
    *,
    mesh=None,
    strategy: Optional[str] = None,
    tuning=None,
    lens: Optional[np.ndarray] = None,
    prefill_fn=None,
    step_fn=None,
) -> np.ndarray:
    """prompts: (B, S_prompt) int32 -> (B, S_prompt + max_new_tokens).

    ``mesh`` routes the forward through ``planned_matmuls`` (see module
    docstring); ``strategy`` pins the schedule inside that scope;
    ``tuning`` (a ``repro.tune`` table or ``Tuner``) prices in-scope plans
    with measured kernel seconds.  ``lens``
    gives per-request true lengths of a left-padded batch; models with
    ``supports_position_offsets`` then decode each row at its own logical
    positions.  ``prefill_fn``/``step_fn`` inject persistent compiled
    functions (``repro.serve.Server``); by default fresh jit wrappers are
    built per call.
    """
    b, sp = prompts.shape
    if b == 0:
        return np.asarray(prompts)
    cfg.validate_prompt_len(sp)
    cache = model.init_cache(b, cfg.max_seq)
    key = key if key is not None else jax.random.PRNGKey(0)

    offsets = None
    if lens is not None and getattr(model, "supports_position_offsets", False):
        offsets = jnp.asarray(sp - np.asarray(lens), jnp.int32)

    tokens = jnp.asarray(prompts, jnp.int32)
    out = [tokens]
    scope = planned_scope(mesh, strategy, tuning)
    with scope:
        if prefill_fn is None:
            prefill_fn = _default_prefill(model, mesh, strategy, tuning)
        if step_fn is None:
            step_fn = _default_step(model, mesh, strategy, tuning)
        with obs.span("serve.prefill", batch=b, seq=sp):
            if offsets is not None:
                logits, cache = prefill_fn(params, cache, tokens, offsets)
            else:
                logits, cache = prefill_fn(params, cache, tokens)
        if cfg.max_new_tokens == 0:
            return np.asarray(tokens)
        cur = _sample(logits, cfg, key)
        out.append(cur[:, None])
        for t in range(sp, sp + cfg.max_new_tokens - 1):
            key, sub = jax.random.split(key)
            with obs.span("serve.decode_step", batch=b, pos=t):
                if offsets is not None:
                    logits, cache = step_fn(params, cache, cur[:, None],
                                            jnp.int32(t), offsets)
                else:
                    logits, cache = step_fn(params, cache, cur[:, None],
                                            jnp.int32(t))
            cur = _sample(logits, cfg, sub)
            out.append(cur[:, None])
    return np.asarray(jnp.concatenate(out, axis=1))


def planned_scope(mesh, strategy: Optional[str] = None, tuning=None):
    """The plan-routing scope ``generate`` decodes under: route through
    ``planned_matmuls(mesh, strategy, tuning)`` when a multi-device mesh is
    given, otherwise a null context (the local GSPMD baseline path)."""
    if mesh is not None and getattr(mesh, "size", 1) > 1:
        from repro.plan import planned_matmuls

        return planned_matmuls(mesh, strategy, tuning)
    return contextlib.nullcontext()


@functools.lru_cache(maxsize=None)
def _default_prefill(model, mesh=None, strategy: Optional[str] = None,
                     tuning=None):
    """Memoized per (model, mesh, strategy) prefill: one-pass for models
    with ``prefill`` (DecoderLM), teacher-forced step loop otherwise
    (recurrent families).

    The plan scope is (re-)entered INSIDE the jitted closure, not just
    around the call: JAX's trace cache is keyed on the traced callable,
    and equal bound methods (``model.prefill``) would share a jaxpr traced
    earlier WITHOUT the scope -- silently skipping plan routing.  A
    closure per (model, mesh, strategy, tuning) gets its own trace-cache
    entry and
    reads the contextvar while tracing; the memo makes repeated
    ``generate`` calls with the same config reuse it instead of retracing.
    """
    if hasattr(model, "prefill"):
        def prefill(params, cache, tokens, offsets=None):
            with planned_scope(mesh, strategy, tuning):
                if offsets is not None:
                    return model.prefill(params, cache, tokens, offsets)
                return model.prefill(params, cache, tokens)

        return jax.jit(prefill)
    step = _default_step(model, mesh, strategy, tuning)

    def loop(params, cache, tokens):
        logits = None
        for t in range(tokens.shape[1]):
            logits, cache = step(params, cache, tokens[:, t : t + 1],
                                 jnp.int32(t))
        return logits, cache

    return loop


@functools.lru_cache(maxsize=None)
def _default_step(model, mesh=None, strategy: Optional[str] = None,
                  tuning=None):
    def step(params, cache, tokens, pos, offsets=None):
        with planned_scope(mesh, strategy, tuning):
            if offsets is not None:
                return model.decode_step(params, cache, tokens, pos, offsets)
            return model.decode_step(params, cache, tokens, pos)

    return jax.jit(step)


def _sample(logits: jax.Array, cfg: ServeConfig, key) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / cfg.temperature, axis=-1).astype(
        jnp.int32
    )


def batch_requests(
    prompt_list: Sequence[Sequence[int]], pad_id: int = 0,
    *, pad_to: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Left-pad a list of variable-length prompts into one (B, S) batch.

    Returns ``(batch, lens)``: ``lens[i]`` is request i's true length --
    pass it to ``generate(lens=...)`` so padded rows decode at their own
    logical positions.  An empty request list yields an explicit empty
    (0, 0) batch (generate returns it unchanged).  ``pad_to`` pads the
    sequence axis to a fixed width (the bucket router's seq bucket).
    """
    if not prompt_list:
        return (np.zeros((0, pad_to or 0), np.int32),
                np.zeros((0,), np.int32))
    maxlen = max(len(p) for p in prompt_list)
    if pad_to is not None:
        if pad_to < maxlen:
            raise ValueError(
                f"pad_to={pad_to} shorter than longest prompt ({maxlen})")
        maxlen = pad_to
    batch = np.full((len(prompt_list), maxlen), pad_id, np.int32)
    lens = np.zeros(len(prompt_list), np.int32)
    for i, pr in enumerate(prompt_list):
        if len(pr) == 0:
            raise ValueError(f"request {i} is empty; prompts need >= 1 token")
        batch[i, maxlen - len(pr):] = pr
        lens[i] = len(pr)
    return batch, lens
