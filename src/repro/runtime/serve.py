"""Serving runtime: batched greedy/temperature decoding over the KV cache.

``generate`` drives model.decode_step with a single jit'd step (position is
a traced scalar, so one compile serves the whole generation).  Prompts are
consumed through the same step (teacher forcing) -- robust across every
model family here, including the recurrent ones whose prefill is the
recurrence itself.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    max_seq: int = 256


def generate(
    model, params, prompts: np.ndarray, cfg: ServeConfig,
    key: Optional[jax.Array] = None,
) -> np.ndarray:
    """prompts: (B, S_prompt) int32 -> (B, S_prompt + max_new_tokens)."""
    b, sp = prompts.shape
    cache = model.init_cache(b, cfg.max_seq)
    step_fn = jax.jit(model.decode_step)
    key = key if key is not None else jax.random.PRNGKey(0)

    tokens = jnp.asarray(prompts, jnp.int32)
    out = [tokens]
    if hasattr(model, "prefill"):
        # one-pass prompt ingestion through the cached path (DecoderLM)
        logits, cache = jax.jit(model.prefill)(params, cache, tokens)
    else:
        logits = None
        for t in range(sp):
            logits, cache = step_fn(params, cache, tokens[:, t : t + 1],
                                    jnp.int32(t))
    cur = _sample(logits, cfg, key)
    out.append(cur[:, None])
    for t in range(sp, sp + cfg.max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, cache = step_fn(params, cache, cur[:, None], jnp.int32(t))
        cur = _sample(logits, cfg, sub)
        out.append(cur[:, None])
    return np.asarray(jnp.concatenate(out, axis=1))


def _sample(logits: jax.Array, cfg: ServeConfig, key) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / cfg.temperature, axis=-1).astype(
        jnp.int32
    )


def batch_requests(prompt_list, pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Left-pad a list of variable-length prompts into one batch."""
    maxlen = max(len(p) for p in prompt_list)
    batch = np.full((len(prompt_list), maxlen), pad_id, np.int32)
    lens = np.zeros(len(prompt_list), np.int32)
    for i, pr in enumerate(prompt_list):
        batch[i, maxlen - len(pr):] = pr
        lens[i] = len(pr)
    return batch, lens
