"""Elastic re-meshing: rebuild a smaller mesh after pod/node loss and
re-place training state onto it.

TPU failures are pod-granular for ICI meshes: losing any chip takes its
slice out of the ICI torus, so the recovery unit is a pod.  The policy here:
drop the failed pod from the ``pod`` axis (multi-pod -> fewer pods, or
single-pod mesh), reshard from the latest checkpoint, continue with the
global batch preserved (per-device batch grows) or reduced, per config.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.sharding_rules import param_shardings


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[np.ndarray] = None) -> Mesh:
    if devices is None:
        n = int(np.prod(shape))
        devices = np.array(jax.devices()[:n])
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices)


def shrink_after_failure(mesh: Mesh, lost_pod: int = 0) -> Mesh:
    """Return the survivor mesh after losing one pod."""
    names = mesh.axis_names
    if "pod" in names and mesh.shape["pod"] > 1:
        devs = np.asarray(mesh.devices)
        pod_axis = names.index("pod")
        keep = [i for i in range(mesh.shape["pod"]) if i != lost_pod]
        new_devs = np.take(devs, keep, axis=pod_axis)
        if len(keep) == 1:
            new_devs = np.squeeze(new_devs, axis=pod_axis)
            new_names = tuple(n for n in names if n != "pod")
            return Mesh(new_devs, new_names)
        return Mesh(new_devs, names)
    raise ValueError("no pod axis to shrink; replace failed hosts instead")


def replace_state(state: Any, mesh: Mesh) -> Any:
    """Re-place (reshard) an optimizer-state tree onto ``mesh``."""
    psh = param_shardings(state["master"], mesh)
    rep = NamedSharding(mesh, P())
    shardings = {"step": rep, "master": psh, "m": psh, "v": psh}
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s),
                        state, shardings)
