"""Mesh context + activation sharding constraints.

Models are mesh-agnostic: they call ``constrain(x, "batch", None, "model")``
with *logical* axes; inside a ``use_mesh`` context these resolve to the
physical mesh ("batch" -> every data-parallel axis present: ("pod","data")
multi-pod, ("data",) single-pod) and become with_sharding_constraint; with
no mesh active (CPU smoke tests) they are identity.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: ContextVar[Optional[Mesh]] = ContextVar("repro_mesh", default=None)

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    token = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(token)


def resolve_axis(logical, mesh: Mesh):
    if logical == "batch":
        axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
        return axes if axes else None
    if logical == "model":
        return MODEL_AXIS if MODEL_AXIS in mesh.axis_names else None
    if logical == "data":
        return "data" if "data" in mesh.axis_names else None
    return logical


def logical_spec(*logical_axes) -> Tuple:
    return logical_axes


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """Apply a sharding constraint given logical axis names (or None)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = P(*(resolve_axis(a, mesh) for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical_axes) -> NamedSharding:
    return NamedSharding(mesh, P(*(resolve_axis(a, mesh) for a in logical_axes)))


def planned_matmul_axes(d_in: int, d_out: int, *, mesh: Optional[Mesh] = None,
                        tokens: int = 8192, dtype_bytes: int = 2) -> Tuple:
    """Partition axes for a (d_in, d_out) weight, ranked by ``plan.estimate``.

    Column-parallel ``(None, 'model')`` means the activations must be
    gathered along the contraction (the ring_ag / all-gather schedule:
    tokens x d_in words move); row-parallel ``('model', None)`` means the
    partial outputs must be reduce-scattered (ring_rs: tokens x d_out
    words).  Pricing both 1-D torus solutions with the plan cost model
    recovers the Megatron convention -- column-parallel up-projections,
    row-parallel down-projections -- from the word counts instead of
    hand-written rules, and keeps working when d_in ~ d_out.
    """
    mesh = mesh if mesh is not None else _MESH.get()
    tp = mesh.shape.get(MODEL_AXIS, 1) if mesh is not None else 1
    if tp <= 1:
        return (None, None)
    from repro.plan import estimate

    col = estimate("ring_ag", tokens, d_out, d_in, tp, dtype_bytes)
    row = estimate("ring_rs", tokens, d_out, d_in, tp, dtype_bytes)
    return (None, MODEL_AXIS) if col.total_s <= row.total_s else (MODEL_AXIS, None)
