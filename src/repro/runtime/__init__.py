"""Runtime: training loop (fault tolerance), serving, elastic re-meshing,
mesh-context sharding helpers.

Submodules are imported directly (``from repro.runtime import train``
style) rather than eagerly here: ``runtime.sharding`` is a leaf dependency
of the layer/data packages and eager imports would cycle.
"""
