"""Training runtime: jit'd train step + fault-tolerant outer loop.

Design points for 1000+ node runs:
  * the step function is a pure function of (opt_state, batch) with donated
    state -- no python-side parameter copies;
  * compute params are cast from fp32 masters inside the step (bf16 compute,
    fp32 trajectory);
  * checkpoints are written asynchronously every ``ckpt_every`` steps and on
    failure the loop restores the latest complete checkpoint -- including
    onto a *different* mesh (elastic restart: pod loss shrinks the mesh and
    training continues at reduced throughput rather than stopping);
  * a step-time watchdog flags stragglers (on TPU SPMD a straggler is a
    host-side stall; the mitigation hook logs and, past a threshold,
    triggers the same re-mesh path as a failure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import store
from repro.data.pipeline import device_put_batch
from repro.models.sharding_rules import param_shardings
from repro.optim import adamw
from repro.runtime.sharding import use_mesh


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    fail_at_step: Optional[int] = None   # fault injection (tests/examples)
    max_restarts: int = 2


class Trainer:
    def __init__(self, model, train_cfg: TrainConfig, mesh: Optional[Mesh] = None):
        self.model = model
        self.cfg = train_cfg
        self.mesh = mesh
        self.opt_cfg = adamw.AdamWConfig()
        self.sched = adamw.warmup_cosine(train_cfg.lr, train_cfg.warmup, train_cfg.steps)
        self._dtypes = None

    # -- state ----------------------------------------------------------------
    def init_state(self, key) -> Dict[str, Any]:
        params = self.model.init(key)
        self._dtypes = jax.tree.map(lambda p: p.dtype, params)
        state = adamw.init(params)
        if self.mesh is not None:
            shardings = self._state_shardings(state)
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return state

    def _state_shardings(self, state):
        assert self.mesh is not None
        psh = param_shardings(state["master"], self.mesh)
        rep = NamedSharding(self.mesh, P())
        return {"step": rep, "master": psh, "m": psh, "v": psh}

    # -- step -----------------------------------------------------------------
    def make_train_step(self) -> Callable:
        model, sched, opt_cfg = self.model, self.sched, self.opt_cfg
        dtypes = self._dtypes

        def loss_of_master(master, batch):
            params = jax.tree.map(lambda w, t: w.astype(t), master, dtypes)
            return model.loss(params, batch)

        def train_step(state, batch):
            lr = sched(state["step"])
            (loss, metrics), grads = jax.value_and_grad(
                loss_of_master, has_aux=True
            )(state["master"], batch)
            new_state, opt_metrics = adamw.step(state, grads, lr, opt_cfg)
            return new_state, {"loss": loss, **metrics, **opt_metrics}

        if self.mesh is None:
            return jax.jit(train_step, donate_argnums=(0,))
        sh = self._state_shardings  # resolved lazily against live state
        return jax.jit(train_step, donate_argnums=(0,))

    # -- loop -----------------------------------------------------------------
    def fit(
        self, key, data_iter: Iterator[Dict[str, np.ndarray]],
        state: Optional[Dict] = None,
    ) -> Dict[str, Any]:
        cfg = self.cfg
        restarts = 0
        start_step = 0
        if state is None:
            state = self.init_state(key)
        else:
            self._dtypes = jax.tree.map(
                lambda p: jnp.bfloat16 if p.dtype == jnp.float32 else p.dtype,
                state["master"],
            )
        if cfg.ckpt_dir and store.latest_step(cfg.ckpt_dir) is not None:
            start_step, state = store.restore(cfg.ckpt_dir, state)
        train_step = self.make_train_step()
        writer = store.AsyncWriter()
        history = []
        step_times = []
        step = start_step
        injected = False

        with use_mesh(self.mesh):
            while step < cfg.steps:
                batch = device_put_batch(next(data_iter), self.mesh)
                t0 = time.perf_counter()
                try:
                    if cfg.fail_at_step == step and not injected:
                        injected = True
                        raise RuntimeError("injected node failure")
                    state, metrics = train_step(state, batch)
                    jax.block_until_ready(metrics["loss"])
                except Exception as e:  # noqa: BLE001 -- restart boundary
                    restarts += 1
                    if restarts > cfg.max_restarts or not cfg.ckpt_dir:
                        raise
                    writer.wait()
                    latest = store.latest_step(cfg.ckpt_dir)
                    print(f"[trainer] step {step} failed ({e}); "
                          f"restoring step {latest} and continuing")
                    state = self.init_state(jax.random.PRNGKey(0))
                    step, state = store.restore(cfg.ckpt_dir, state)
                    train_step = self.make_train_step()
                    continue
                dt = time.perf_counter() - t0
                step_times.append(dt)
                med = float(np.median(step_times[-20:]))
                if dt > cfg.straggler_factor * med and len(step_times) > 5:
                    print(f"[trainer] straggler: step {step} took {dt:.2f}s "
                          f"(median {med:.2f}s)")
                step += 1
                if step % cfg.log_every == 0 or step == cfg.steps:
                    loss = float(metrics["loss"])
                    history.append({"step": step, "loss": loss,
                                    "sec_per_step": dt})
                    print(f"[trainer] step {step:5d} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if cfg.ckpt_dir and step % cfg.ckpt_every == 0:
                    writer.save(cfg.ckpt_dir, step, state)
            writer.wait()
            if cfg.ckpt_dir:
                store.save(cfg.ckpt_dir, step, state)
        return {"state": state, "history": history, "restarts": restarts}
