"""Architecture configs: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_smoke_config(name)`` a reduced same-family config for CPU smoke tests.
``SHAPES`` defines the assigned input-shape cells; ``runnable_cells()``
enumerates the (arch x shape) grid minus the documented skips
(DESIGN.md Sec. 5).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

ARCHS = (
    "llama3_2_1b",
    "granite_20b",
    "minicpm3_4b",
    "h2o_danube3_4b",
    "chameleon_34b",
    "qwen3_moe_30b_a3b",
    "deepseek_moe_16b",
    "seamless_m4t_medium",
    "xlstm_350m",
    "zamba2_2_7b",
)

# canonical ids from the assignment -> module names
ALIASES = {
    "llama3.2-1b": "llama3_2_1b",
    "granite-20b": "granite_20b",
    "minicpm3-4b": "minicpm3_4b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "chameleon-34b": "chameleon_34b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-350m": "xlstm_350m",
    "zamba2-2.7b": "zamba2_2_7b",
}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic decode; DESIGN.md Sec. 5)
LONG_CONTEXT_OK = {"xlstm_350m", "zamba2_2_7b", "h2o_danube3_4b"}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE


def runnable_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
                continue
            cells.append((arch, shape))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    return [
        (arch, "long_500k", "full-attention arch: 500k dense-KV decode is not sub-quadratic")
        for arch in ARCHS if arch not in LONG_CONTEXT_OK
    ]
