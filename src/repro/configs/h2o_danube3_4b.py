"""h2o-danube-3-4b [dense, SWA]: 24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000; llama+mistral mix with sliding-window attention
(window 4096).  [arXiv:2401.16818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    window=4096,
    remat="dots",
)

SMOKE = ModelConfig(
    name="h2o-danube-3-4b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    window=16, attn_chunk=32,
)
