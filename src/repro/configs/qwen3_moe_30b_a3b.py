"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768
vocab=151936; 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    num_experts=128, top_k=8, moe_d_ff=768,
    remat="dots",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256, head_dim=16,
    num_experts=8, top_k=2, moe_d_ff=96, moe_group_size=32, attn_chunk=32,
)
