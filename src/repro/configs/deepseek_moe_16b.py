"""deepseek-moe-16b [moe]: 28L d_model=2048 16H d_ff(expert)=1408
vocab=102400; 2 shared + 64 routed experts top-6, fine-grained; first layer
dense (d_ff 10944).  [arXiv:2401.06066; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, head_dim=128,
    num_experts=64, num_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1,
    remat="dots",
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=8, num_shared_experts=1, top_k=2, moe_d_ff=48,
    first_dense_layers=1, moe_group_size=32, attn_chunk=32,
)
