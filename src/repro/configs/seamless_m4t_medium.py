"""seamless-m4t-medium [audio]: enc-dec, 12L each, d_model=1024 16H
d_ff=4096 vocab=256206.  Backbone only: the speech frontend is a stub --
input_specs provides precomputed frame embeddings (B, S_src, d).
[arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    enc_layers=12, dec_layers=12,
    remat="dots",
)

SMOKE = ModelConfig(
    name="seamless-m4t-smoke", family="audio",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    enc_layers=2, dec_layers=2, attn_chunk=32,
)
