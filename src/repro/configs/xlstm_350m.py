"""xlstm-350m [ssm]: 24L d_model=1024 4H vocab=50304; sLSTM + mLSTM blocks
(pattern mmm-s), no FFN.  [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_chunk=256, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="ssm",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=256, head_dim=16,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_chunk=16, tie_embeddings=True,
)
