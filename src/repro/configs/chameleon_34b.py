"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion -- image VQ tokens share the text vocabulary, so
the backbone is a plain decoder and the modality frontend (VQ tokenizer) is
a stub: input_specs supplies interleaved text+image token ids.
[arXiv:2405.09818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    remat="dots",
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=512, head_dim=16, attn_chunk=32,
)
