"""zamba2-2.7b [hybrid]: 54L d_model=2560 d_ff=10240 vocab=32000,
Mamba2 backbone (state 64) + one shared attention block (32H) applied every
6 layers on concat(hidden, embedding).  [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    shared_attn_every=6,
    remat="dots",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
    shared_attn_every=2, attn_chunk=32,
)
