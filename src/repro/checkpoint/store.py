"""Sharded numpy checkpoints with manifest, atomic rename, async writer,
and elastic restore (a checkpoint written on one mesh restores onto any
other mesh: arrays are saved unsharded and re-placed per the declared
PartitionSpecs at load).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json ; <dir>/LATEST points at
the newest complete step (written last, so a crash mid-write never corrupts
the restore path).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "//"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    """numpy has no bfloat16: exotic dtypes are saved as uint16/uint8 views
    with the true dtype recorded in ``__dtypes__`` for restore."""
    flat = {}
    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path
        )
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype) \
                or "float8" in str(arr.dtype):
            dtypes[key] = str(arr.dtype)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    flat["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8
    )
    return flat


def _unflatten_into(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    dtypes = {}
    if "__dtypes__" in arrays:
        dtypes = json.loads(bytes(arrays["__dtypes__"]).decode())
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = _SEP.join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path
        )
        arr = arrays[key]
        want = dtypes.get(key)
        if want and str(arr.dtype) != want:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Blocking save; returns the step directory."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp_dir, "arrays.npz"), **flat)
    manifest = {"step": step, "num_arrays": len(flat), **(extra or {})}
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)  # idempotent re-save of the same step
    os.replace(tmp_dir, step_dir)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return step_dir


class AsyncWriter:
    """One-in-flight background checkpoint writer (device_get happens on the
    caller thread so the training arrays are snapshotted synchronously; only
    file IO is off-thread)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, ckpt_dir: str, step: int, tree: Any, extra=None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(ckpt_dir, step, host_tree, extra), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    return int(name.split("_")[-1])


def restore(
    ckpt_dir: str, template: Any, step: Optional[int] = None,
    place: Optional[Callable[[Any], Any]] = None,
) -> Tuple[int, Any]:
    """Load into the structure of ``template``; ``place`` re-shards each
    restored tree onto the current mesh (elastic restore)."""
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(step_dir, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    tree = _unflatten_into(template, arrays)
    if place is not None:
        tree = place(tree)
    return step, tree
