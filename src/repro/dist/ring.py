"""Ring collective matmuls: 1-D torus solutions of the paper's equations.

On a 1-D torus the equivariance equations admit exactly the one-hop shift
solutions; executed, they are the classic ring algorithms.  Both functions
run INSIDE ``shard_map`` over a single named axis and decompose the
all-gather / reduce-scatter into a chain of one-hop ``ppermute`` steps, each
overlapped with the matmul of the chunk currently resident -- XLA's
latency-hiding scheduler turns the permute chain into async
collective-permute-start/done pairs running under the per-chunk matmuls
(the paper's Sec. 5 future-work item (f)).

Layout contracts (local shards, ``axis`` the ring axis of size t):

  ring_ag_matmul : x (..., S/t, D) row-sharded, w (D, F/t) col-sharded
                   -> (..., S, F/t)   ("all-gather then matmul", fused)
  ring_rs_matmul : y (..., S, F/t) col-sharded, w (F/t, D) row-sharded
                   -> (..., S/t, D)   ("matmul then reduce-scatter", fused)

Both support 2-D and batched 3-D left operands.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs

from . import _collectives
from .local import local_matmul


def _ring_perm(n: int):
    """One-hop +1 shift on the ring: the mu = 1 movement homomorphism."""
    return [(d, (d + 1) % n) for d in range(n)]


def ring_ag_matmul(x: jax.Array, w: jax.Array, axis, *,
                   out_dtype=None, local_fn=None) -> jax.Array:
    """Fused all-gather(x) @ w_local over ring axis ``axis`` (a mesh axis
    name, or a tuple of names treated as one flattened ring).

    Each of the t steps multiplies the resident x-chunk against the local
    weight shard and writes the product into its global row slot, while the
    chunk ring-shifts one hop for the next step.
    """
    local_fn = local_fn or local_matmul
    n = lax.psum(1, axis)
    idx = lax.axis_index(axis)
    if out_dtype is None:
        out_dtype = jnp.result_type(x.dtype, w.dtype)
    chunk = x.shape[-2]
    out_shape = x.shape[:-2] + (n * chunk, w.shape[-1])
    out = jnp.zeros(out_shape, out_dtype)
    perm = _ring_perm(n)
    cur = x
    for s in range(n):
        # issue the permute first so it overlaps the matmul below
        nxt = None
        if s < n - 1:
            with obs.span("dist.prefetch", comm="hidden"):
                nxt = _collectives.ppermute(cur, axis, perm)
        prod = local_fn(cur, w, out_dtype=out_dtype)
        src = (idx - s) % n  # origin device of the resident chunk
        start = (0,) * (len(out_shape) - 2) + (src * chunk, 0)
        out = lax.dynamic_update_slice(out, prod, start)
        cur = nxt
    return out


def ring_rs_matmul(y: jax.Array, w: jax.Array, axis, *,
                   out_dtype=None, local_fn=None) -> jax.Array:
    """Fused (y @ w_local) reduce-scatter over ring axis ``axis`` (a mesh
    axis name or tuple of names flattened into one ring).

    The local partial product is full-height; the reduction walks the ring
    accumulating the row-chunk destined for each device, one hop per step.
    """
    local_fn = local_fn or local_matmul
    n = lax.psum(1, axis)
    idx = lax.axis_index(axis)
    if out_dtype is None:
        out_dtype = jnp.result_type(y.dtype, w.dtype)
    partial = local_fn(y, w, out_dtype=jnp.float32)
    rows = partial.shape[-2]
    if rows % n:
        raise ValueError(f"rows {rows} not divisible by ring size {n}")
    chunk = rows // n
    slab = partial.shape[:-2] + (chunk, partial.shape[-1])
    perm = _ring_perm(n)
    acc: Optional[jax.Array] = None
    for s in range(n):
        c = (idx + n - 1 - s) % n  # chunk index this device contributes now
        start = (0,) * (len(slab) - 2) + (c * chunk, 0)
        mine = lax.dynamic_slice(partial, start, slab)
        acc = mine if acc is None else acc + mine
        if s < n - 1:
            acc = _collectives.ppermute(acc, axis, perm)
    return acc.astype(out_dtype)
