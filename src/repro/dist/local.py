"""Local (per-device) block matmul shared by every dist strategy.

On TPU/GPU large 2-D blocks route through the Pallas Z-order matmul kernel
(repro.kernels.matmul); everywhere else -- CPU backends, batched operands,
blocks too small to tile -- the fallback is ``jnp.matmul`` with fp32
accumulation, which is also the numerics contract the tests pin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_PALLAS_MIN_TILE = 128


def _pallas_eligible(a: jax.Array, b: jax.Array) -> bool:
    if jax.default_backend() not in ("tpu", "gpu"):
        return False
    if a.ndim != 2 or b.ndim != 2:
        return False
    m, k = a.shape
    n = b.shape[-1]
    return min(m, n, k) >= _PALLAS_MIN_TILE


def local_matmul(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    """``a @ b`` with fp32 accumulation, Pallas-accelerated when possible."""
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    if _pallas_eligible(a, b):
        from repro.kernels.matmul import matmul as pallas_matmul

        # out_dtype forwarded so fp32 accumulators stay fp32 end-to-end:
        # the kernel's scratch is fp32 and must not round through a.dtype
        return pallas_matmul(a, b, out_dtype=out_dtype)
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)
