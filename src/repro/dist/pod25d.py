"""2.5D strategies: replicate--compute--reduce over a pod axis (Sec. D.1).

``Torus25DSchedule`` splits the contraction index j = j_c * (q/c) + j_t: the
c-part selects a pod layer (each layer owns a contraction slab), the t-part
runs an in-layer 2-D schedule, and C is reduced over the pod axis at the
end.  Here the pod split composes with either in-layer strategy:

  pod25d_matmul    -- slab matmul per layer (SUMMA in-layer when the mesh
                      also has 2-D axes), then psum over the pod axis
  cannon25d_matmul -- in-layer Cannon on the slab (the executed
                      ``cannon_schedule(q)`` ppermute program of
                      repro.dist.cannon), then psum over the pod axis

The replication half of the trade (each layer holding a full copy of its
operand panels) is expressed by the in_specs: operands are sharded over
(pod x in-layer) axes jointly, so each layer starts with exactly its slab
and no cross-layer broadcast is needed beyond XLA's initial layout.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.schedule import cannon_schedule
from repro.jax_compat import shard_map

from .cannon import _pad_to, torus_body
from .local import local_matmul


def _inlayer_axes(mesh, pod_axis: str, axis_x: Optional[str],
                  axis_y: Optional[str]) -> Tuple[Optional[str], Optional[str]]:
    if axis_x is not None and axis_y is not None:
        return axis_x, axis_y
    rest = [nm for nm in mesh.axis_names if nm != pod_axis]
    if len(rest) >= 2:
        return rest[0], rest[1]
    return None, None


def pod25d_matmul(a: jax.Array, b: jax.Array, *, mesh,
                  pod_axis: str = "pod",
                  axis_x: Optional[str] = None, axis_y: Optional[str] = None,
                  out_dtype=None) -> jax.Array:
    """Global matmul with the contraction split over ``pod_axis``.  When the
    mesh carries two more axes the in-layer phase is SUMMA over them;
    otherwise each layer multiplies its full slab locally."""
    c = mesh.shape[pod_axis]
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")
    ax, ay = _inlayer_axes(mesh, pod_axis, axis_x, axis_y)

    if ax is None:
        ap = _pad_to(a, (1, c))
        bp = _pad_to(b, (c, 1))

        def body(ab, bb):
            part = local_matmul(ab, bb, out_dtype=jnp.float32)
            return lax.psum(part, pod_axis).astype(out_dtype)

        f = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, pod_axis), P(pod_axis, None)),
            out_specs=P(None, None),
        )
        out = f(ap, bp)
        return out[:m, :n] if out.shape != (m, n) else out

    qx, qy = mesh.shape[ax], mesh.shape[ay]
    ap = _pad_to(a, (qx, c * qx * qy))
    bp = _pad_to(b, (c * qx * qy, qy))

    def body(ab, bb):
        # within layer z: A cols / B rows cover contraction slab z
        arow = lax.all_gather(ab, ay, axis=1, tiled=True)  # (M/qx, K/c)
        bcol = lax.all_gather(bb, ax, axis=0, tiled=True)  # (K/c, N/qy)
        part = local_matmul(arow, bcol, out_dtype=jnp.float32)
        return lax.psum(part, pod_axis).astype(out_dtype)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(ax, (pod_axis, ay)), P((pod_axis, ax), ay)),
        out_specs=P(ax, ay),
    )
    out = f(ap, bp)
    return out[:m, :n] if out.shape != (m, n) else out


def cannon25d_matmul(a: jax.Array, b: jax.Array, *, mesh,
                     pod_axis: str = "pod",
                     axis_x: str = "x", axis_y: str = "y",
                     out_dtype=None) -> jax.Array:
    """2.5D with in-layer Cannon: each pod layer executes the solver's
    ``cannon_schedule(q)`` ppermute program on its contraction slab, and C
    partial sums reduce over the pod axis."""
    c = mesh.shape[pod_axis]
    q = mesh.shape[axis_x]
    if mesh.shape[axis_y] != q:
        raise ValueError("in-layer Cannon needs a square (q x q) layer")
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")
    ap = _pad_to(a, (q, c * q))
    bp = _pad_to(b, (c * q, q))

    inner = torus_body(cannon_schedule(q), axis_x, axis_y)

    def body(ab, bb):
        acc = inner(ab, bb)
        return lax.psum(acc, pod_axis).astype(out_dtype)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_x, (pod_axis, axis_y)), P((pod_axis, axis_x), axis_y)),
        out_specs=P(axis_x, axis_y),
    )
    out = f(ap, bp)
    return out[:m, :n] if out.shape != (m, n) else out
