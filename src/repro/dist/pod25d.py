"""2.5D lowering rules: replicate--compute--reduce over a pod axis (Sec. D.1).

``Torus25DSchedule`` splits the contraction index j = j_c * (q/c) + j_t: the
c-part selects a pod layer (each layer owns a contraction slab), the t-part
runs an in-layer 2-D schedule, and C is reduced over the pod axis at the
end.  Here the pod split composes with either in-layer strategy:

  pod25d    -- slab matmul per layer (SUMMA in-layer when the mesh also
               has 2-D axes), then psum over the pod axis
  cannon25d -- in-layer Cannon on the slab (the executed
               ``cannon_schedule(q)`` ppermute program of
               repro.dist.cannon), then psum over the pod axis

The replication half of the trade (each layer holding a full copy of its
operand panels) is expressed by the in_specs the plan compiler emits:
operands are sharded over (pod x in-layer) axes jointly, so each layer
starts with exactly its slab and no cross-layer broadcast is needed beyond
XLA's initial layout.  The ``*_body`` functions are the lowering rules
consumed by ``repro.plan.lower_shard_map``; the ``*_matmul`` entry points
are facades over the plan engine.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import _collectives
from .cannon import torus_program_body, torus_program_body_overlapped
from .local import local_matmul
from .summa import summa_overlapped_body


def _inlayer_axes(mesh, pod_axis: str, axis_x: Optional[str],
                  axis_y: Optional[str]) -> Tuple[Optional[str], Optional[str]]:
    if axis_x is not None and axis_y is not None:
        return axis_x, axis_y
    rest = [nm for nm in mesh.axis_names if nm != pod_axis]
    if len(rest) >= 2:
        return rest[0], rest[1]
    return None, None


def pod25d_slab_body(pod_axis: str, out_dtype, local_fn=None):
    """Lowering rule, no in-layer axes: each layer multiplies its full
    contraction slab locally, then C reduces over the pod axis."""
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        part = local_fn(ab, bb, out_dtype=jnp.float32)
        return _collectives.psum(part, pod_axis).astype(out_dtype)

    return body


def pod25d_summa_body(pod_axis: str, axis_x: str, axis_y: str, out_dtype,
                      local_fn=None):
    """Lowering rule, SUMMA in-layer: within layer z the A-columns / B-rows
    cover contraction slab z; gather panels, multiply, reduce over pod."""
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        arow = _collectives.all_gather(ab, axis_y, axis=1, tiled=True)  # (M/qx, K/c)
        bcol = _collectives.all_gather(bb, axis_x, axis=0, tiled=True)  # (K/c, N/qy)
        part = local_fn(arow, bcol, out_dtype=jnp.float32)
        return _collectives.psum(part, pod_axis).astype(out_dtype)

    return body


def pod25d_summa_overlapped_body(pod_axis: str, axis_x: str, axis_y: str,
                                 out_dtype, local_fn=None):
    """Overlapped in-layer variant of ``pod25d_summa_body``: the layer's
    gathers run as pipelined one-hop chains (``summa_overlapped_body`` on
    the k/c contraction slab).  The pod psum consumes the finished partial
    sum, so it stays monolithic -- only the in-layer movement overlaps."""
    inner = summa_overlapped_body(axis_x, axis_y, jnp.float32,
                                  local_fn=local_fn)

    def body(ab, bb):
        part = inner(ab, bb)
        return _collectives.psum(part, pod_axis).astype(out_dtype)

    return body


def cannon25d_body(pod_axis: str, axis_x: str, axis_y: str, prog,
                   out_dtype, local_fn=None, overlap: bool = False):
    """Lowering rule, Cannon in-layer: each pod layer executes the reified
    torus program ``prog`` (the solver's ``cannon_schedule(q)`` ppermute
    program) on its contraction slab, and C partial sums reduce over the
    pod axis.  ``overlap`` selects the double-buffered in-layer body (the
    pod psum is data-dependent and stays after the layer finishes)."""
    body_fn = torus_program_body_overlapped if overlap else torus_program_body
    inner = body_fn(prog, axis_x, axis_y, local_fn=local_fn)

    def body(ab, bb):
        acc = inner(ab, bb)
        return _collectives.psum(acc, pod_axis).astype(out_dtype)

    return body


def pod25d_matmul(a: jax.Array, b: jax.Array, *, mesh,
                  pod_axis: str = "pod",
                  axis_x: Optional[str] = None, axis_y: Optional[str] = None,
                  out_dtype=None) -> jax.Array:
    """Global matmul with the contraction split over ``pod_axis``.  When the
    mesh carries two more axes the in-layer phase is SUMMA over them;
    otherwise each layer multiplies its full slab locally."""
    from repro.plan import build_plan, execute_plan

    ax, ay = _inlayer_axes(mesh, pod_axis, axis_x, axis_y)
    axes = (pod_axis,) if ax is None else (pod_axis, ax, ay)
    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, strategy="pod25d",
        axes=axes, batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
    )
    return execute_plan(plan, a, b)


def cannon25d_matmul(a: jax.Array, b: jax.Array, *, mesh,
                     pod_axis: str = "pod",
                     axis_x: str = "x", axis_y: str = "y",
                     out_dtype=None) -> jax.Array:
    """2.5D with in-layer Cannon: each pod layer executes the solver's
    ``cannon_schedule(q)`` ppermute program on its contraction slab, and C
    partial sums reduce over the pod axis."""
    from repro.plan import build_plan, execute_plan

    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, strategy="cannon25d",
        axes=(pod_axis, axis_x, axis_y), batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
    )
    return execute_plan(plan, a, b)
