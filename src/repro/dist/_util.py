"""Shared helpers for the dist lowering rules."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def pad_to(x: jax.Array, mults: Tuple[int, ...]) -> jax.Array:
    """Zero-pad each dim of ``x`` up to the next multiple of ``mults``.

    Every strategy pads its operands onto the device grid this way and
    slices the product back; zero rows/columns contribute nothing to the
    matmul so the result is exact.
    """
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(hi for _, hi in pads):
        return jnp.pad(x, pads)
    return x
