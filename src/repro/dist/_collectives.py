"""Interceptable collective seam for the dist lowering rules.

Every word a lowered schedule moves goes through one of these three
wrappers; they are plain pass-throughs to ``jax.lax`` in production.
``repro.verify.interceptor`` monkeypatches them (within a context manager)
to count the collectives a shard_map body actually emits -- the measured
leg of the trace == interceptor == cost-model conformance triangle.

Only *data-movement* calls route through here.  Axis-size queries
(``lax.psum(1, axis)``) and anything outside the strategy bodies call
``jax.lax`` directly and are invisible to the interceptor, exactly as they
are invisible to the cost model.
"""
from __future__ import annotations

from jax import lax


def ppermute(x, axis_name, perm):
    return lax.ppermute(x, axis_name, perm)


def all_gather(x, axis_name, *, axis, tiled):
    return lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


def psum(x, axis_name):
    return lax.psum(x, axis_name)
