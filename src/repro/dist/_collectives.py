"""Interceptable collective seam for the dist lowering rules.

Every word a lowered schedule moves goes through one of these three
wrappers; they are plain pass-throughs to ``jax.lax`` in production.
``repro.verify.interceptor`` monkeypatches them (within a context manager)
to count the collectives a shard_map body actually emits -- the measured
leg of the trace == interceptor == cost-model conformance triangle.

When ``repro.obs`` tracing is enabled, each call additionally records one
``CollectiveEvent`` (kind, axis-group size, shard words, canonical perm,
ambient strategy tag) in the obs recorder and bumps the per-kind metrics
counters -- the same key shape the verify interceptor captures, so
``obs.collective_multiset()`` must equal the interceptor's multiset
exactly (the drift check asserts it).  Because the interceptor patches
*these* names and calls the originals, both layers observe the same calls
when active together.

Only *data-movement* calls route through here.  Axis-size queries
(``lax.psum(1, axis)``) and anything outside the strategy bodies call
``jax.lax`` directly and are invisible to the interceptor, exactly as they
are invisible to the cost model.
"""
from __future__ import annotations

import math

from jax import lax

from repro import obs


def _observe(kind: str, x, axis_name, perm=None) -> None:
    """Record one collective in the obs layer (enabled-mode only)."""
    group = int(lax.psum(1, axis_name))  # static axis-size query
    words = int(math.prod(x.shape)) if getattr(x, "shape", None) else 1
    obs.record_collective(kind, group, words, perm)
    obs.counter("dist.collective.count").inc(kind=kind)
    obs.counter("dist.collective.words").inc(words, kind=kind)


def ppermute(x, axis_name, perm):
    if obs.enabled():
        _observe("ppermute", x, axis_name, perm)
    return lax.ppermute(x, axis_name, perm)


def all_gather(x, axis_name, *, axis, tiled):
    if obs.enabled():
        _observe("all_gather", x, axis_name)
    return lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


def psum(x, axis_name):
    if obs.enabled():
        _observe("psum", x, axis_name)
    return lax.psum(x, axis_name)
