"""SUMMA: the broadcast-based stationary-C strategy, for contrast with
Cannon's permute chains.

SUMMA's per-step row/column panel broadcasts, summed over the q steps, are
exactly a tiled all-gather of A along the mesh columns and of B along the
mesh rows -- which is how XLA lowers them on a torus -- so the staged
lowering rule (``summa_body``) emits the fused form: two all-gathers plus
one local matmul.  Monolithic gathers cannot hide behind compute, so the
overlapped rule (``summa_overlapped_body``) decomposes them into one-hop
ppermute chains: the B column panel is chain-gathered first (nothing to
multiply yet -- exposed), then the A k-slabs walk their ring with each hop
issued *before* the partial multiply against the matching B slab, hiding
the A movement under compute.  Both bodies move the identical per-device
words ((qy-1) A-shards + (qx-1) B-shards); the overlapped output differs
from the staged single-dot only by fp32 summation order.

Unlike Cannon, SUMMA tolerates rectangular meshes (axis_x != axis_y sizes).
The ``*_body`` functions are the lowering rules consumed by
``repro.plan.lower_shard_map``; ``summa_matmul`` is a facade over the plan
engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs

from . import _collectives
from .local import local_matmul


def summa_body(axis_x: str, axis_y: str, out_dtype, local_fn=None):
    """shard_map body: tiled all-gathers of the A-row / B-column panels
    followed by one local multiply (the fused SUMMA step sum)."""
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        arow = _collectives.all_gather(ab, axis_y, axis=1, tiled=True)  # (M/qx, K)
        bcol = _collectives.all_gather(bb, axis_x, axis=0, tiled=True)  # (K, N/qy)
        return local_fn(arow, bcol, out_dtype=out_dtype)

    return body


def gather_chain(x: jax.Array, axis_name: str) -> jax.Array:
    """One-hop ppermute chain equivalent of
    ``all_gather(x, axis_name, axis=0, tiled=True)``: each of the g - 1
    steps writes the resident shard into its origin slot and forwards it
    one hop around the ring.  Moves the same (g - 1) shards per device as
    the monolithic gather, but as individually schedulable one-hop
    permutes -- the decomposition that lets SUMMA join the overlapped
    family."""
    g = int(lax.psum(1, axis_name))
    idx = lax.axis_index(axis_name)
    rows = x.shape[0]
    out = jnp.zeros((g * rows,) + x.shape[1:], x.dtype)
    perm = [(d, (d + 1) % g) for d in range(g)]
    cur = x
    for s in range(g):
        src = (idx - s) % g  # origin device of the resident shard
        out = lax.dynamic_update_slice(
            out, cur, (src * rows,) + (0,) * (x.ndim - 1))
        if s < g - 1:
            cur = _collectives.ppermute(cur, axis_name, perm)
    return out


def summa_overlapped_body(axis_x: str, axis_y: str, out_dtype,
                          local_fn=None):
    """shard_map body: pipelined SUMMA with decomposed gathers.

    Phase 1 chain-gathers the full B column panel over ``axis_x`` (exposed:
    there is nothing to compute against yet).  Phase 2 walks A's k-slabs
    around the ``axis_y`` ring, issuing each hop BEFORE the partial multiply
    against the matching slice of the B panel, so the permute hides under
    the compute (the ring prefetch trick on the torus row)."""
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        bcol = gather_chain(bb, axis_x)                    # (K, N/qy)
        qy = int(lax.psum(1, axis_y))
        iy = lax.axis_index(axis_y)
        ky = ab.shape[1]                                   # K / qy
        perm = [(d, (d + 1) % qy) for d in range(qy)]
        acc = jnp.zeros((ab.shape[0], bb.shape[1]), jnp.float32)
        cur = ab
        for s in range(qy):
            nxt = None
            if s < qy - 1:
                with obs.span("dist.prefetch", comm="hidden"):
                    nxt = _collectives.ppermute(cur, axis_y, perm)
            src = (iy - s) % qy  # k-slab index of the resident A chunk
            bslab = lax.dynamic_slice(
                bcol, (src * ky, 0), (ky, bcol.shape[1]))
            acc = acc + local_fn(cur, bslab, out_dtype=jnp.float32)
            cur = nxt
        return acc.astype(out_dtype)

    return body


def summa_matmul(a: jax.Array, b: jax.Array, *, mesh,
                 axis_x: str = "x", axis_y: str = "y",
                 out_dtype=None, overlap=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul, SUMMA-scheduled over (axis_x, axis_y).

    ``overlap=False`` forces the staged body (monolithic tiled
    all-gathers); ``overlap=True`` the one-hop gather-chain body; the
    default lets the planner pick (see ``repro.plan.build_plan``)."""
    from repro.plan import build_plan, execute_plan

    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, strategy="summa",
        axes=(axis_x, axis_y), batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
        overlap=overlap,
    )
    return execute_plan(plan, a, b)
