"""SUMMA: the broadcast-based stationary-C strategy, for contrast with
Cannon's permute chains.

SUMMA's per-step row/column panel broadcasts, summed over the q steps, are
exactly a tiled all-gather of A along the mesh columns and of B along the
mesh rows -- which is how XLA lowers them on a torus -- so the engine emits
the fused form: two all-gathers plus one local matmul.  Same asymptotic
words as Cannon (each device receives (q-1)/q of a row + column panel) but
as monolithic all-gathers, not overlappable one-hop permutes; the HLO
difference is visible in examples/distributed_matmul.py.

Unlike Cannon, SUMMA tolerates rectangular meshes (axis_x != axis_y sizes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.jax_compat import shard_map

from .cannon import _pad_to
from .local import local_matmul


def summa_matmul(a: jax.Array, b: jax.Array, *, mesh,
                 axis_x: str = "x", axis_y: str = "y",
                 out_dtype=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul, SUMMA-scheduled over (axis_x, axis_y)."""
    qx, qy = mesh.shape[axis_x], mesh.shape[axis_y]
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")
    # K is split by qy on A's columns and by qx on B's rows
    ap = _pad_to(a, (qx, qx * qy))
    bp = _pad_to(b, (qx * qy, qy))

    def body(ab, bb):
        arow = lax.all_gather(ab, axis_y, axis=1, tiled=True)  # (M/qx, K)
        bcol = lax.all_gather(bb, axis_x, axis=0, tiled=True)  # (K, N/qy)
        return local_matmul(arow, bcol, out_dtype=out_dtype)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_x, axis_y), P(axis_x, axis_y)),
        out_specs=P(axis_x, axis_y),
    )
    out = f(ap, bp)
    if out.shape != (m, n):
        out = out[:m, :n]
    return out
