"""SUMMA: the broadcast-based stationary-C strategy, for contrast with
Cannon's permute chains.

SUMMA's per-step row/column panel broadcasts, summed over the q steps, are
exactly a tiled all-gather of A along the mesh columns and of B along the
mesh rows -- which is how XLA lowers them on a torus -- so the lowering rule
emits the fused form: two all-gathers plus one local matmul.  Same
asymptotic words as Cannon (each device receives (q-1)/q of a row + column
panel) but as monolithic all-gathers, not overlappable one-hop permutes;
the HLO difference is visible in examples/distributed_matmul.py.

Unlike Cannon, SUMMA tolerates rectangular meshes (axis_x != axis_y sizes).
``summa_body`` is the lowering rule consumed by
``repro.plan.lower_shard_map``; ``summa_matmul`` is a facade over the plan
engine.
"""
from __future__ import annotations

import jax

from . import _collectives
from .local import local_matmul


def summa_body(axis_x: str, axis_y: str, out_dtype, local_fn=None):
    """shard_map body: tiled all-gathers of the A-row / B-column panels
    followed by one local multiply (the fused SUMMA step sum)."""
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        arow = _collectives.all_gather(ab, axis_y, axis=1, tiled=True)  # (M/qx, K)
        bcol = _collectives.all_gather(bb, axis_x, axis=0, tiled=True)  # (K, N/qy)
        return local_fn(arow, bcol, out_dtype=out_dtype)

    return body


def summa_matmul(a: jax.Array, b: jax.Array, *, mesh,
                 axis_x: str = "x", axis_y: str = "y",
                 out_dtype=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul, SUMMA-scheduled over (axis_x, axis_y)."""
    from repro.plan import build_plan, execute_plan

    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, strategy="summa",
        axes=(axis_x, axis_y), batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
    )
    return execute_plan(plan, a, b)
