"""Execute equivariant torus schedules as shard_map/ppermute programs.

This is the algebra->execution bridge: a valid ``TorusSchedule`` (a solution
of the paper's commutative-diagram equations, e.g. out of
``repro.core.solver``) is lowered to a data-parallel program whose every
data movement is a ``ppermute`` whose permutation comes verbatim from the
schedule:

  * the initial skew is ``schedule.placement_perm(var)`` -- the schedule's
    l_I layout (for Cannon, the classic A_ij -> P_{i, j-i} skew),
  * each time step shifts A/B/C by ``schedule.movement_perm(var)`` -- the
    movement homomorphism mu translated to (src, dst) device pairs,
  * the output is collected by ``schedule.collection_perm("C", t-1)``
    (identity for stationary-C schedules like Cannon, and then skipped).

``cannon_matmul`` is the engine applied to ``cannon_schedule(q)``; any other
valid solver solution executes through ``torus_schedule_matmul`` unchanged.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.schedule import TorusSchedule, cannon_schedule
from repro.jax_compat import shard_map

from .local import local_matmul


def lowered_plan(schedule: TorusSchedule) -> Dict:
    """The complete ppermute program for ``schedule``: per-step shift
    vectors, one-step movement perms, initial-skew perms, and the final
    C-collection perm.  Everything the executor runs comes from here."""
    moves = schedule.movements()
    if moves is None:
        raise ValueError("schedule has no consistent movement homomorphisms")
    return {
        "q": schedule.q,
        "steps": schedule.t,
        "shifts": moves,  # {var: (mu_x, mu_y)} -- the solver's solution
        "skew": {v: schedule.placement_perm(v) for v in ("A", "B")},
        "step_perm": {v: schedule.movement_perm(v) for v in ("A", "B", "C")},
        "collect_C": schedule.collection_perm("C", schedule.t - 1),
    }


def executed_shift_vectors(q: int) -> Dict[str, Tuple[int, int]]:
    """Per-step (dx, dy) each variable set moves in ``cannon_matmul`` -- by
    construction the movement homomorphisms of the solver's Cannon solution
    (pinned by tests/test_dist_consistency.py)."""
    return lowered_plan(cannon_schedule(q))["shifts"]


def _is_identity(perm) -> bool:
    return perm is None or all(src == dst for src, dst in perm)


def _permute(x, axes, perm):
    if _is_identity(perm):
        return x
    return lax.ppermute(x, axes, perm)


def _pad_to(x: jax.Array, mults: Tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(hi for _, hi in pads):
        return jnp.pad(x, pads)
    return x


def torus_body(schedule: TorusSchedule, axis_x: str, axis_y: str):
    """shard_map body executing ``schedule`` on local (M/q, K/q) x (K/q, N/q)
    blocks; returns the fp32 accumulator in canonical C layout.  Shared by
    cannon_matmul and the in-layer phase of cannon25d_matmul."""
    plan = lowered_plan(schedule)
    axes = (axis_x, axis_y)

    def body(ab, bb):
        ab = _permute(ab, axes, plan["skew"]["A"])
        bb = _permute(bb, axes, plan["skew"]["B"])
        acc = jnp.zeros((ab.shape[0], bb.shape[1]), jnp.float32)
        for step in range(plan["steps"]):
            acc = acc + local_matmul(ab, bb, out_dtype=jnp.float32)
            if step < plan["steps"] - 1:
                ab = _permute(ab, axes, plan["step_perm"]["A"])
                bb = _permute(bb, axes, plan["step_perm"]["B"])
                acc = _permute(acc, axes, plan["step_perm"]["C"])
        return _permute(acc, axes, plan["collect_C"])

    return body


def torus_schedule_matmul(a: jax.Array, b: jax.Array,
                          schedule: TorusSchedule, *, mesh,
                          axis_x: str = "x", axis_y: str = "y",
                          out_dtype=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul executing ``schedule`` on the q x q
    torus spanned by mesh axes (axis_x, axis_y).  Operands are zero-padded
    to block multiples and the result sliced back."""
    q = schedule.q
    if mesh.shape[axis_x] != q or mesh.shape[axis_y] != q:
        raise ValueError(
            f"mesh axes ({mesh.shape[axis_x]}, {mesh.shape[axis_y]}) "
            f"do not span the schedule's {q} x {q} torus")
    if schedule.t != q:
        raise ValueError("executor supports the t = q schedule family")
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")
    ap = _pad_to(a, (q, q))
    bp = _pad_to(b, (q, q))

    body = torus_body(schedule, axis_x, axis_y)
    f = shard_map(
        lambda ab, bb: body(ab, bb).astype(out_dtype),
        mesh=mesh,
        in_specs=(P(axis_x, axis_y), P(axis_x, axis_y)),
        out_specs=P(axis_x, axis_y),
    )
    out = f(ap, bp)
    if out.shape != (m, n):
        out = out[:m, :n]
    return out


def cannon_matmul(a: jax.Array, b: jax.Array, *, mesh,
                  axis_x: str = "x", axis_y: str = "y",
                  out_dtype=None) -> jax.Array:
    """Cannon's algorithm as the executed solver solution: skewed initial
    layout + one-hop A/B shifts, all ppermutes from ``cannon_schedule(q)``."""
    q = mesh.shape[axis_x]
    return torus_schedule_matmul(
        a, b, cannon_schedule(q), mesh=mesh,
        axis_x=axis_x, axis_y=axis_y, out_dtype=out_dtype)
