"""Torus-schedule lowering rules: equivariant schedules as ppermute bodies.

This is the algebra->execution bridge: a valid ``TorusSchedule`` (a solution
of the paper's commutative-diagram equations, e.g. out of
``repro.core.solver``) lowers to a data-parallel program whose every
data movement is a ``ppermute`` whose permutation comes verbatim from the
schedule:

  * the initial skew is ``schedule.placement_perm(var)`` -- the schedule's
    l_I layout (for Cannon, the classic A_ij -> P_{i, j-i} skew),
  * each time step shifts A/B/C by ``schedule.movement_perm(var)`` -- the
    movement homomorphism mu translated to (src, dst) device pairs,
  * the output is collected by ``schedule.collection_perm("C", t-1)``
    (identity for stationary-C schedules like Cannon, and then skipped).

``torus_body`` is the lowering *rule*: the shard_map body consumed by
``repro.plan.lower_shard_map`` (and by the in-layer phase of the 2.5D
rule in ``repro.dist.pod25d``).  The entry points ``cannon_matmul`` /
``torus_schedule_matmul`` are thin facades over ``repro.plan``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.schedule import TorusSchedule, cannon_schedule

from . import _collectives
from ._util import pad_to
from .local import local_matmul

# retained import location: callers historically did
# ``from repro.dist.cannon import _pad_to`` -- the shared helper now lives
# in repro.dist._util
_pad_to = pad_to


def lowered_plan(schedule: TorusSchedule) -> Dict:
    """The complete ppermute program for ``schedule``: per-step shift
    vectors, one-step movement perms, initial-skew perms, and the final
    C-collection perm.  Everything the executor runs comes from here (and
    ``repro.plan.ir.TorusProgram`` reifies it as static IR)."""
    moves = schedule.movements()
    if moves is None:
        raise ValueError("schedule has no consistent movement homomorphisms")
    return {
        "q": schedule.q,
        "steps": schedule.t,
        "shifts": moves,  # {var: (mu_x, mu_y)} -- the solver's solution
        "skew": {v: schedule.placement_perm(v) for v in ("A", "B")},
        "step_perm": {v: schedule.movement_perm(v) for v in ("A", "B", "C")},
        "collect_C": schedule.collection_perm("C", schedule.t - 1),
    }


def executed_shift_vectors(q: int) -> Dict[str, Tuple[int, int]]:
    """Per-step (dx, dy) each variable set moves in ``cannon_matmul`` -- by
    construction the movement homomorphisms of the solver's Cannon solution
    (pinned by tests/test_dist_consistency.py)."""
    return lowered_plan(cannon_schedule(q))["shifts"]


def _is_identity(perm) -> bool:
    return perm is None or all(src == dst for src, dst in perm)


def _permute(x, axes, perm):
    if _is_identity(perm):
        return x
    return _collectives.ppermute(x, axes, list(perm))


def torus_program_body(prog, axis_x: str, axis_y: str, local_fn=None):
    """shard_map body executing a reified torus program on local
    (M/q, K/q) x (K/q, N/q) blocks; returns the fp32 accumulator in
    canonical C layout.  ``prog`` is anything carrying the program fields
    (``repro.plan.ir.TorusProgram``, or the view ``torus_body`` builds from
    a schedule): steps, skew_a/b, step_a/b/c, collect_c.  The local block
    multiply is ``local_fn`` (default ``local_matmul``; the plan compiler
    passes its Pallas tiling lowering here)."""
    axes = (axis_x, axis_y)
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        ab = _permute(ab, axes, prog.skew_a)
        bb = _permute(bb, axes, prog.skew_b)
        acc = jnp.zeros((ab.shape[0], bb.shape[1]), jnp.float32)
        for step in range(prog.steps):
            acc = acc + local_fn(ab, bb, out_dtype=jnp.float32)
            if step < prog.steps - 1:
                ab = _permute(ab, axes, prog.step_a)
                bb = _permute(bb, axes, prog.step_b)
                acc = _permute(acc, axes, prog.step_c)
        return _permute(acc, axes, prog.collect_c)

    return body


def torus_program_body_overlapped(prog, axis_x: str, axis_y: str,
                                  local_fn=None):
    """Double-buffered variant of ``torus_program_body`` (collective-matmul
    style): step k+1's A/B ppermutes are issued BEFORE step k's local
    multiply -- the same prefetch trick ``repro.dist.ring`` uses on 1-D
    rings -- so XLA's latency-hiding scheduler can run the permutes
    asynchronously under the matmul.  C's per-step permute consumes the
    fresh partial sum and must stay after the multiply (it is the exposed
    remainder).

    The permutes and multiplies are the *identical* operations of the
    staged body in a reordered data-flow: every ``local_fn`` call sees the
    same operands and the accumulator chain is unchanged, so outputs are
    bitwise-identical and the collective multiset is the same (the
    conformance harness checks both)."""
    axes = (axis_x, axis_y)
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        ab = _permute(ab, axes, prog.skew_a)
        bb = _permute(bb, axes, prog.skew_b)
        acc = jnp.zeros((ab.shape[0], bb.shape[1]), jnp.float32)
        for step in range(prog.steps):
            nxt_a = nxt_b = None
            if step < prog.steps - 1:
                with obs.span("dist.prefetch", comm="hidden"):
                    nxt_a = _permute(ab, axes, prog.step_a)
                    nxt_b = _permute(bb, axes, prog.step_b)
            acc = acc + local_fn(ab, bb, out_dtype=jnp.float32)
            if step < prog.steps - 1:
                acc = _permute(acc, axes, prog.step_c)
                ab, bb = nxt_a, nxt_b
        return _permute(acc, axes, prog.collect_c)

    return body


def torus_body(schedule: TorusSchedule, axis_x: str, axis_y: str,
               local_fn=None):
    """``torus_program_body`` over the program reified from ``schedule``
    (the same ``TorusProgram`` the plan IR carries -- one field mapping,
    shared by the schedule-direct and plan paths)."""
    from repro.plan.ir import TorusProgram

    return torus_program_body(TorusProgram.from_schedule(schedule),
                              axis_x, axis_y, local_fn=local_fn)


def torus_schedule_matmul(a: jax.Array, b: jax.Array,
                          schedule: TorusSchedule, *, mesh,
                          axis_x: str = "x", axis_y: str = "y",
                          out_dtype=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul executing ``schedule`` on the q x q
    torus spanned by mesh axes (axis_x, axis_y).  Facade over the plan
    engine: builds a torus plan carrying the schedule and executes its
    shard_map lowering (operands zero-padded to block multiples, result
    sliced back)."""
    from repro.plan import build_plan, execute_plan

    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, schedule=schedule,
        axes=(axis_x, axis_y), batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
    )
    return execute_plan(plan, a, b)


def cannon_matmul(a: jax.Array, b: jax.Array, *, mesh,
                  axis_x: str = "x", axis_y: str = "y",
                  out_dtype=None) -> jax.Array:
    """Cannon's algorithm as the executed solver solution: skewed initial
    layout + one-hop A/B shifts, all ppermutes from ``cannon_schedule(q)``."""
    q = mesh.shape[axis_x]
    return torus_schedule_matmul(
        a, b, cannon_schedule(q), mesh=mesh,
        axis_x=axis_x, axis_y=axis_y, out_dtype=out_dtype)
