"""Strategy cost model + the public dispatch facade over ``repro.plan``.

``estimate`` prices a strategy with the paper's word-counting applied to the
TPU constants in ``repro.core.cost`` (ICI link bandwidth, peak MXU flops):
compute time is the per-device share of 2mnk flops, communication time is
the strategy's per-device received bytes over one ICI link, and overlapped
strategies pay max(compute, comm) instead of the sum -- that inequality is
exactly why the one-hop solutions win.  Whether a cell is overlapped is no
longer keyed on the strategy *name*: ``overlap_capability`` reports which
lowerings have a double-buffered body (since the overlapped execution mode
that includes SUMMA's decomposed gather chains), and ``estimate``'s
``overlap`` argument pins one variant so the planner can price the
staged-vs-overlapped pair of the same program.

``choose`` ranks the strategies applicable to a device count / mesh
topology with the cost model (topology acts only as a *filter*) and returns
the cheapest; ``symmetric_matmul`` dispatches a global matmul through the
plan engine: ``repro.plan.build_plan`` (cached) + ``execute_plan``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax

from repro.core import cost as _cost

STRATEGIES = (
    "cannon", "summa", "cannon25d", "pod25d", "fattree",
    "ring_ag", "ring_rs", "xla_ag", "xla_rs", "local",
)


@dataclasses.dataclass(frozen=True)
class Estimate:
    """Analytic cost record for one (strategy, problem, parallelism) cell.

    ``msgs`` is the per-device collective-round count (ppermute rounds,
    ring steps of a gather/reduce) -- the latency term a calibrated α–β
    ranking (``repro.obs.MachineProfile.seconds``) charges α for; the
    analytic ``total_s`` itself prices bandwidth only.

    ``overlapped`` is the *variant* this cell prices (max vs. sum); it is
    derived from the lowering's capability (``overlap_capability``), not
    the strategy name.  ``comm_by_axis`` splits ``comm_bytes``/``msgs``
    into per-mesh-axis ``(axis_name, bytes, msgs)`` terms when the caller
    supplies the resolved axis roles -- the hook a calibrated profile with
    per-axis ``axis:{name}`` link classes prices each term with its own
    α–β (empty when axes are unknown or the strategy flattens them).

    ``tree_level_words`` (hierarchical strategies only) is the analytic
    per-level traffic of the inter-pod tree axis: entry l-1 is the
    mesh-wide *element* count (dtype-agnostic words, the conformance
    convention) crossing tree level l (1 = leaf pairs, last = root) over
    the whole run.  For the fat-tree schedule level l is crossed by the
    s / 2^(l-1) - 1 exchanges whose Gray mask reaches bit l-1, each moving
    all of A once -- so the root entry is exactly m*k, the paper's "n^2
    words of A cross the top link".
    """

    strategy: str
    m: int
    n: int
    k: int
    tp: int
    compute_s: float
    comm_s: float
    comm_bytes: float
    overlapped: bool
    msgs: int = 0
    comm_by_axis: Tuple[Tuple[str, float, int], ...] = ()
    tree_level_words: Tuple[float, ...] = ()

    @property
    def total_s(self) -> float:
        if self.overlapped:
            return max(self.compute_s, self.comm_s)
        return self.compute_s + self.comm_s


def overlap_capability(strategy: str, grid=None) -> bool:
    """Whether ``strategy``'s lowering has a double-buffered (overlapped)
    body: the ring chains are intrinsically overlapped, the torus family
    prefetches step k+1's A/B permutes under step k's multiply, and SUMMA /
    3-axis pod25d run their gathers as pipelined one-hop chains.  The
    1-axis pod25d slab program (``grid == (c,)``), the hierarchical
    fat-tree program (each super-step's gather feeds the slab multiply it
    precedes -- no independent round to hide it under), and the
    XLA-collective / local baselines have no overlapped variant."""
    if strategy in ("ring_ag", "ring_rs", "cannon", "cannon25d", "summa"):
        return True
    if strategy == "pod25d":
        return grid is None or len(grid) >= 3
    return False


def _square_side(tp: int) -> Optional[int]:
    q = int(math.isqrt(tp))
    return q if q * q == tp and q > 1 else None


def _pod_factor(tp: int) -> Optional[tuple]:
    """Largest c > 1 with tp = q^2 * c and q > 1, preferring small pods."""
    best = None
    for c in (2, 3, 4, 8):
        if tp % c:
            continue
        q = _square_side(tp // c)
        if q:
            best = (q, c)
            break
    return best


def _tree_factor(tp: int) -> tuple:
    """Canonical (s, q) with tp = s * q^2, s a power of two >= 2, for
    grid-less fat-tree estimates (mesh-aware callers always pass the real
    grid); degrades to trivial intra-pod axes when tp has no square
    cofactor."""
    for s in (2, 4, 8):
        if tp % s == 0:
            q = _square_side(tp // s)
            if q:
                return s, q
    return 2, max(int(math.isqrt(max(tp // 2, 1))), 1)


def estimate(strategy: str, m: int, n: int, k: int, tp: int,
             dtype_bytes: int = 2, *, grid=None, axes=None,
             overlap: Optional[bool] = None) -> Estimate:
    """Analytic cost of ``strategy`` for an (m, k) x (k, n) matmul on ``tp``
    devices.  ``total_s`` = max(compute, comm) for overlapped variants,
    sum otherwise.

    ``grid`` optionally pins the device-grid factorization the lowering
    will actually run -- ``(qx, qy)`` for the 2-D torus strategies,
    ``(c, qx, qy)`` (or ``(c,)``) for the 2.5D family -- so mesh-aware
    rankings (``repro.plan.rank_mesh_strategies``) price the real program
    rather than the canonical factorization of ``tp`` derived here.

    ``axes`` optionally names the mesh axes each communication term rides
    (the plan's resolved axis roles, matching ``grid``); when given, the
    estimate carries per-axis ``comm_by_axis`` terms summing exactly to
    ``comm_bytes``/``msgs`` so a profile with per-axis link classes prices
    each axis with its own α–β.

    ``overlap`` pins the variant: ``None`` prices the lowering's default
    (overlapped whenever ``overlap_capability`` allows), ``False`` the
    staged twin, ``True`` demands overlap and raises for strategies with
    no overlapped body.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    capability = overlap_capability(strategy, grid)
    if overlap is None:
        overlapped = capability
    elif overlap and not capability:
        raise ValueError(
            f"strategy {strategy!r} (grid={grid}) has no overlapped lowering")
    else:
        overlapped = bool(overlap)
    compute_s = 2.0 * m * n * k / tp / _cost.PEAK_FLOPS_BF16
    axis_terms = []
    tree_levels: Tuple[float, ...] = ()
    if strategy == "local" or tp == 1:
        comm_bytes = 0.0
        msgs = 0
    elif strategy in ("xla_ag", "ring_ag"):
        # gather the row-sharded (m, k) operand: receive (tp-1)/tp of it
        comm_bytes = dtype_bytes * m * k * (tp - 1) / tp
        msgs = tp - 1
        if axes is not None and len(axes) == 1:
            axis_terms = [(axes[0], comm_bytes, msgs)]
    elif strategy in ("xla_rs", "ring_rs"):
        # reduce-scatter the (m, n) partial output
        comm_bytes = dtype_bytes * m * n * (tp - 1) / tp
        msgs = tp - 1
        if axes is not None and len(axes) == 1:
            axis_terms = [(axes[0], comm_bytes, msgs)]
    elif strategy in ("cannon", "summa"):
        if grid is not None:
            qx, qy = grid[0], grid[1]
        else:
            qx = qy = _square_side(tp) or max(int(math.isqrt(tp)), 2)
        # per device: the (m/qx, k) row panel from qy-1 peers and the
        # (k, n/qy) column panel from qx-1 peers (equal to the classic
        # (q-1) * 2 block panels when qx == qy)
        a_bytes = dtype_bytes * (qy - 1) * (m / qx) * (k / qy)
        b_bytes = dtype_bytes * (qx - 1) * (k / qx) * (n / qy)
        comm_bytes = a_bytes + b_bytes
        # cannon: 2 skews + (q-1) rounds x {A, B}; summa: ring gathers
        msgs = 2 * qx if strategy == "cannon" else (qx - 1) + (qy - 1)
        if axes is not None and len(axes) >= 2:
            # A panels move along the column axis, B panels along the row
            # axis (cannon splits its 2q rounds evenly; summa's chain
            # lengths are the gather-group sizes minus one)
            ma, mb = (qx, qx) if strategy == "cannon" else (qy - 1, qx - 1)
            axis_terms = [(axes[1], a_bytes, ma), (axes[0], b_bytes, mb)]
    elif strategy in ("pod25d", "cannon25d"):
        if grid is not None:
            c = grid[0]
            qx = grid[1] if len(grid) > 1 else 1
            qy = grid[2] if len(grid) > 2 else qx
        else:
            q, c = _pod_factor(tp) or (_square_side(tp) or 2, 1)
            qx = qy = q
        # in-layer panel exchange on the (qx, qy) layer over the k/c slab
        a_bytes = dtype_bytes * (qy - 1) * (m / qx) * (k / (c * qy))
        b_bytes = dtype_bytes * (qx - 1) * (k / (c * qx)) * (n / qy)
        reduce_bytes = \
            dtype_bytes * (c - 1) / c * (m / qx) * (n / qy) * 2  # repl+reduce
        comm_bytes = a_bytes + b_bytes + reduce_bytes
        in_layer = 2 * qx if strategy == "cannon25d" else \
            max((qx - 1) + (qy - 1), 0)
        msgs = in_layer + 2 * (c - 1)  # + bidirectional pod-ring reduce
        if axes is not None and len(axes) >= 3:
            ma, mb = (qx, qx) if strategy == "cannon25d" else \
                (max(qy - 1, 0), max(qx - 1, 0))
            axis_terms = [(axes[2], a_bytes, ma), (axes[1], b_bytes, mb),
                          (axes[0], reduce_bytes, 2 * (c - 1))]
        elif axes is not None and len(axes) == 1:
            axis_terms = [(axes[0], comm_bytes, msgs)]
    elif strategy == "fattree":
        if grid is not None:
            s = grid[0]
            qx = grid[1] if len(grid) > 1 else 1
            qy = grid[2] if len(grid) > 2 else qx
        else:
            s, q = _tree_factor(tp)
            qx = qy = q
        # inter-pod: s - 1 XOR exchanges of each device's A slab shard;
        # intra-pod: per super-step column gather of the slab shard plus
        # one hoisted row gather of the stationary B panel
        a_exch = dtype_bytes * (s - 1) * (m / qx) * (k / (s * qy))
        a_gather = dtype_bytes * s * (qy - 1) * (m / qx) * (k / (s * qy))
        b_gather = dtype_bytes * (qx - 1) * (k / qx) * (n / (s * qy))
        comm_bytes = a_exch + a_gather + b_gather
        msgs = (s - 1) + s * (qy - 1) + (qx - 1)
        if axes is not None and len(axes) >= 3:
            axis_terms = [(axes[0], a_exch, s - 1),
                          (axes[2], a_gather, s * (qy - 1)),
                          (axes[1], b_gather, qx - 1)]
        # per-level tree traffic (mesh-wide element words): level l is
        # crossed by the s/2^(l-1) - 1 exchanges whose mask reaches bit
        # l-1, and each exchange moves all m*k words of A once
        dt = max(s.bit_length() - 1, 1)
        tree_levels = tuple(
            float((s // (1 << (lvl - 1)) - 1) * m * k)
            for lvl in range(1, dt + 1))
    else:  # pragma: no cover
        raise AssertionError(strategy)
    comm_s = comm_bytes / _cost.ICI_BW
    comm_by_axis = tuple(
        (str(a), float(b), int(ms)) for a, b, ms in axis_terms)
    return Estimate(strategy, m, n, k, tp, compute_s, comm_s, comm_bytes,
                    overlapped, msgs, comm_by_axis, tree_levels)


def applicable_strategies(tp: int) -> tuple:
    """Strategies executable on ``tp`` devices (topology permitting)."""
    if tp <= 1:
        return ("local",)
    out = ["ring_ag", "ring_rs"]
    if _square_side(tp):
        out += ["cannon", "summa"]
    if _pod_factor(tp):
        out += ["cannon25d", "pod25d"]
    return tuple(out)


def _mesh_heuristic(mesh, m: int = 1, n: int = 1, k: int = 1) -> str:
    """The pre-plan topology-shape heuristic, kept for reference and as a
    regression foil: beyond the 1-D ring tie-break it ignores the problem
    shape entirely, so it disagrees with the cost model e.g. on a square
    mesh with a huge contraction dimension (Cannon moves O(k) panel bytes;
    reduce-scattering the small output is cheaper).  tests/test_plan.py
    pins one such disagreement."""
    tp = mesh.size
    axes = len(mesh.axis_names)
    if tp == 1:
        return "local"
    if axes == 1:
        # 1-D torus: move whichever tensor is smaller around the ring
        return "ring_ag" if m * k <= m * n else "ring_rs"
    if axes == 2:
        sizes = [mesh.shape[nm] for nm in mesh.axis_names]
        return "cannon" if sizes[0] == sizes[1] else "summa"
    names = mesh.axis_names
    if mesh.shape[names[1]] == mesh.shape[names[2]]:
        return "cannon25d"
    return "pod25d"  # rectangular in-layer axes: SUMMA in-layer


def choose(m: int, n: int, k: int, *, tp: Optional[int] = None, mesh=None,
           dtype_bytes: int = 2) -> str:
    """Pick the cheapest applicable strategy for the problem shape and the
    mesh topology (or bare device count ``tp``).  Topology only *filters*
    the candidates (``repro.plan.mesh_candidates``); the analytic cost
    model ranks them."""
    if mesh is not None:
        if mesh.size == 1:
            return "local"
        from repro.plan import rank_mesh_strategies

        return rank_mesh_strategies(m, n, k, mesh, dtype_bytes)[0].strategy
    if tp is None:
        raise ValueError("choose() needs tp= or mesh=")
    cands = applicable_strategies(tp)
    est = [estimate(s, m, n, k, tp, dtype_bytes) for s in cands]
    return min(est, key=lambda e: (e.total_s, cands.index(e.strategy))).strategy


def symmetric_matmul(a: jax.Array, b: jax.Array, *, mesh=None,
                     strategy: Optional[str] = None,
                     out_dtype=None,
                     tuning=None,
                     overlap: Optional[bool] = None) -> jax.Array:
    """Global (batch..., M, K) x (K, N) matmul dispatched through the plan
    engine: strategy picked by the cost model over the mesh-applicable
    candidates (or forced via ``strategy``), plan memoized in the plan
    cache, leading batch dims folded before planning.  ``tuning`` (a
    ``repro.tune`` table or ``Tuner``) prices the compute side of the
    ranking with measured kernel seconds and folds the winning blocks into
    the plan's tiling.  ``overlap`` forces the double-buffered (``True``)
    or staged (``False``) lowering; the default lets the planner pick
    (see ``repro.plan.build_plan``)."""
    from repro.plan import build_plan, execute_plan

    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, strategy=strategy,
        batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
        tuning=tuning, overlap=overlap,
    )
    return execute_plan(plan, a, b)
