"""Strategy cost model + auto-dispatch for the dist matmul engines.

``estimate`` prices a strategy with the paper's word-counting applied to the
TPU constants in ``repro.core.cost`` (ICI link bandwidth, peak MXU flops):
compute time is the per-device share of 2mnk flops, communication time is
the strategy's per-device received bytes over one ICI link, and overlapped
strategies (the ring/ppermute family) pay max(compute, comm) instead of the
sum -- that inequality is exactly why the one-hop solutions win.

``choose`` ranks the strategies applicable to a device count / mesh
topology and returns the cheapest; ``symmetric_matmul`` dispatches a global
matmul through it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.core import cost as _cost
from repro.jax_compat import shard_map

from .cannon import cannon_matmul
from .local import local_matmul
from .pod25d import cannon25d_matmul, pod25d_matmul
from .ring import ring_ag_matmul, ring_rs_matmul
from .summa import summa_matmul

STRATEGIES = (
    "cannon", "summa", "cannon25d", "pod25d",
    "ring_ag", "ring_rs", "xla_ag", "xla_rs", "local",
)


@dataclasses.dataclass(frozen=True)
class Estimate:
    """Analytic cost record for one (strategy, problem, parallelism) cell."""

    strategy: str
    m: int
    n: int
    k: int
    tp: int
    compute_s: float
    comm_s: float
    comm_bytes: float
    overlapped: bool

    @property
    def total_s(self) -> float:
        if self.overlapped:
            return max(self.compute_s, self.comm_s)
        return self.compute_s + self.comm_s


def _square_side(tp: int) -> Optional[int]:
    q = int(math.isqrt(tp))
    return q if q * q == tp and q > 1 else None


def _pod_factor(tp: int) -> Optional[tuple]:
    """Largest c > 1 with tp = q^2 * c and q > 1, preferring small pods."""
    best = None
    for c in (2, 3, 4, 8):
        if tp % c:
            continue
        q = _square_side(tp // c)
        if q:
            best = (q, c)
            break
    return best


def estimate(strategy: str, m: int, n: int, k: int, tp: int,
             dtype_bytes: int = 2) -> Estimate:
    """Analytic cost of ``strategy`` for an (m, k) x (k, n) matmul on ``tp``
    devices.  ``total_s`` = max(compute, comm) for overlapped strategies,
    sum otherwise."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    compute_s = 2.0 * m * n * k / tp / _cost.PEAK_FLOPS_BF16
    overlapped = strategy in ("ring_ag", "ring_rs", "cannon", "cannon25d")
    if strategy == "local" or tp == 1:
        comm_bytes = 0.0
    elif strategy in ("xla_ag", "ring_ag"):
        # gather the row-sharded (m, k) operand: receive (tp-1)/tp of it
        comm_bytes = dtype_bytes * m * k * (tp - 1) / tp
    elif strategy in ("xla_rs", "ring_rs"):
        # reduce-scatter the (m, n) partial output
        comm_bytes = dtype_bytes * m * n * (tp - 1) / tp
    elif strategy in ("cannon", "summa"):
        q = _square_side(tp) or max(int(math.isqrt(tp)), 2)
        # per device: (q-1) block panels of A and of B
        comm_bytes = dtype_bytes * (q - 1) * ((m / q) * (k / q) + (k / q) * (n / q))
    elif strategy in ("pod25d", "cannon25d"):
        qc = _pod_factor(tp) or (_square_side(tp) or 2, 1)
        q, c = qc
        shift = (q - 1) * ((m / q) * (k / (c * q)) + (k / (c * q)) * (n / q))
        reduce_c = (c - 1) / c * (m / q) * (n / q) * 2  # replicate + reduce C
        comm_bytes = dtype_bytes * (shift + reduce_c)
    else:  # pragma: no cover
        raise AssertionError(strategy)
    comm_s = comm_bytes / _cost.ICI_BW
    return Estimate(strategy, m, n, k, tp, compute_s, comm_s, comm_bytes,
                    overlapped)


def applicable_strategies(tp: int) -> tuple:
    """Strategies executable on ``tp`` devices (topology permitting)."""
    if tp <= 1:
        return ("local",)
    out = ["ring_ag", "ring_rs"]
    if _square_side(tp):
        out += ["cannon", "summa"]
    if _pod_factor(tp):
        out += ["cannon25d", "pod25d"]
    return tuple(out)


def choose(m: int, n: int, k: int, *, tp: Optional[int] = None, mesh=None,
           dtype_bytes: int = 2) -> str:
    """Pick the cheapest applicable strategy for the problem shape and mesh
    topology (or bare device count ``tp``)."""
    if mesh is not None:
        tp = mesh.size
        axes = len(mesh.axis_names)
        if tp == 1:
            return "local"
        if axes == 1:
            # 1-D torus: move whichever tensor is smaller around the ring
            return "ring_ag" if m * k <= m * n else "ring_rs"
        if axes == 2:
            sizes = [mesh.shape[nm] for nm in mesh.axis_names]
            return "cannon" if sizes[0] == sizes[1] else "summa"
        names = mesh.axis_names
        if mesh.shape[names[1]] == mesh.shape[names[2]]:
            return "cannon25d"
        return "pod25d"  # rectangular in-layer axes: SUMMA in-layer
    if tp is None:
        raise ValueError("choose() needs tp= or mesh=")
    cands = applicable_strategies(tp)
    est = [estimate(s, m, n, k, tp, dtype_bytes) for s in cands]
    return min(est, key=lambda e: (e.total_s, cands.index(e.strategy))).strategy


def symmetric_matmul(a: jax.Array, b: jax.Array, *, mesh=None,
                     strategy: Optional[str] = None,
                     out_dtype=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul dispatched through the strategy picked
    from mesh topology and problem shape (or forced via ``strategy``)."""
    m, k = a.shape
    n = b.shape[-1]
    if mesh is None or mesh.size == 1:
        return local_matmul(a, b, out_dtype=out_dtype)
    if strategy is None:
        strategy = choose(m, n, k, mesh=mesh)
    if strategy in ("cannon", "summa"):
        names = list(mesh.axis_names)
        fn = cannon_matmul if strategy == "cannon" else summa_matmul
        return fn(a, b, mesh=mesh, axis_x=names[0], axis_y=names[1],
                  out_dtype=out_dtype)
    if strategy in ("pod25d", "cannon25d"):
        names = list(mesh.axis_names)
        if strategy == "cannon25d":
            return cannon25d_matmul(a, b, mesh=mesh, pod_axis=names[0],
                                    axis_x=names[1], axis_y=names[2],
                                    out_dtype=out_dtype)
        return pod25d_matmul(a, b, mesh=mesh, pod_axis=names[0],
                             out_dtype=out_dtype)
    if strategy in ("ring_ag", "ring_rs"):
        from .cannon import _pad_to

        axis = mesh.axis_names[0]
        t = mesh.shape[axis]
        if strategy == "ring_ag":
            # sharded dims: m (rows of a) and n (cols of b); zero-pad + slice
            ap, bp = _pad_to(a, (t, 1)), _pad_to(b, (1, t))
            f = shard_map(
                lambda xl, wl: ring_ag_matmul(xl, wl, axis,
                                              out_dtype=out_dtype),
                mesh=mesh,
                in_specs=(P(axis, None), P(None, axis)),
                out_specs=P(None, axis),
            )
            out = f(ap, bp)
        else:
            # sharded dims: the contraction k and the output rows m
            ap, bp = _pad_to(a, (t, t)), _pad_to(b, (t, 1))
            f = shard_map(
                lambda yl, wl: ring_rs_matmul(yl, wl, axis,
                                              out_dtype=out_dtype),
                mesh=mesh,
                in_specs=(P(None, axis), P(axis, None)),
                out_specs=P(axis, None),
            )
            out = f(ap, bp)
        return out[:m, :n] if out.shape != (m, n) else out
    if strategy == "local":
        return local_matmul(a, b, out_dtype=out_dtype)
    raise ValueError(f"cannot dispatch strategy {strategy!r}")
