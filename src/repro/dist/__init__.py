"""repro.dist -- schedule-execution engine.

Lowers the equivariant schedules of ``repro.core`` (solutions of the
paper's commutative-diagram equations) to executable shard_map/ppermute
programs:

  cannon    -- the solver's Cannon solution run verbatim: placement perms
               for the skew, movement-homomorphism perms for the shifts
  summa     -- the broadcast (all-gather) stationary-C contrast strategy
  pod25d    -- Torus25DSchedule's replicate--compute--reduce over a pod
               axis, composable with an in-layer strategy (cannon25d)
  ring      -- the 1-D torus solutions: all-gather / reduce-scatter
               decomposed into one-hop ppermute chains overlapped with
               per-chunk matmuls
  api       -- analytic cost model (estimate), strategy selection (choose),
               and dispatch (symmetric_matmul)

Since the ``repro.plan`` refactor the strategy modules hold the lowering
*rules* (shard_map bodies); program composition -- padding, specs,
batch folding, plan caching -- lives in ``repro.plan.lower_shard_map``
and the entry points here are thin facades over it.

Local block multiplies route through the Pallas matmul kernel on TPU/GPU
and jnp.matmul with fp32 accumulation elsewhere (repro.dist.local).
"""
from repro import jax_compat as _jax_compat

_jax_compat.install()

from ._util import pad_to  # noqa: E402
from .api import (Estimate, applicable_strategies, choose, estimate,  # noqa: E402
                  symmetric_matmul)
from .cannon import (cannon_matmul, executed_shift_vectors,  # noqa: E402
                     lowered_plan, torus_body, torus_schedule_matmul)
from .fattree import fattree_matmul  # noqa: E402
from .local import local_matmul  # noqa: E402
from .pod25d import cannon25d_matmul, pod25d_matmul  # noqa: E402
from .ring import ring_ag_matmul, ring_rs_matmul  # noqa: E402
from .summa import summa_matmul  # noqa: E402

__all__ = [
    "Estimate", "applicable_strategies", "choose", "estimate",
    "symmetric_matmul", "cannon_matmul", "executed_shift_vectors",
    "fattree_matmul", "lowered_plan", "torus_body", "torus_schedule_matmul",
    "local_matmul",
    "cannon25d_matmul", "pod25d_matmul", "pad_to", "ring_ag_matmul",
    "ring_rs_matmul", "summa_matmul",
]
