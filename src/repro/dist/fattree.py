"""Hierarchical fat-tree lowering: the paper's Sec.-4.2 recursive schedule
on a pod-of-pods machine.

The mesh carries one inter-pod **tree** axis (s pods, s a power of two --
the DCN dimension) and an intra-pod (qx, qy) torus pair.  The roles follow
the wreath recursion: C and B column panels are *stationary* per pod (pod p
owns output/operand column block p), while A's contraction slabs walk the
tree axis in the reflected-Gray XOR order

    slab on pod p at super-step t  =  p ^ t

so the exchange between steps is the involution ``d -> d ^ (t ^ (t+1))``
(``repro.core.fattree.tree_exchange_perm``).  The mask's highest bit is the
deepest tree level crossed: the root is crossed exactly once (at
t = s/2 - 1), reproducing the paper's "only A crosses the top link, n^2
words total" claim level by level -- ``repro.verify`` checks the executed
per-level words against both the analytic formula and the k-bit projection
of ``FatTreeSchedule`` itself.

Within a pod each super-step is one broadcast step: B's column panel is
gathered over the rows *once* (hoisted -- B never moves again),
A's resident slab shard is gathered over the columns per step, and the
matching B k-slab is sliced out with the traced slab index.  The
``fattree_body`` function is the lowering rule consumed by
``repro.plan.lower_shard_map``; ``fattree_matmul`` is a facade over the
plan engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.fattree import tree_exchange_perm

from . import _collectives
from .local import local_matmul


def fattree_body(tree_axis: str, axis_x: str, axis_y: str, s: int,
                 out_dtype, local_fn=None):
    """shard_map body for the recursive fat-tree schedule.

    Per-device operands (specs ``P(x, (tree, y))`` for both A and B):

      ab -- (M/qx, K/(s*qy)): pod p's contraction-slab shard of A
      bb -- (K/qx,  N/(s*qy)): the stationary column panel shard of B

    The body runs s super-steps; at step t pod p multiplies A slab
    ``p ^ t`` against the matching k-rows of its gathered B panel, then
    exchanges its resident A shard along the tree axis with the XOR-mask
    involution that advances every pod's slab to ``p ^ (t + 1)``.
    """
    local_fn = local_fn or local_matmul

    def body(ab, bb):
        # hoisted: the stationary B column panel needs its full k extent
        # exactly once (the s slabs are slices of it, not re-gathers)
        bfull = _collectives.all_gather(bb, axis_x, axis=0, tiled=True)
        ks = bfull.shape[0] // s                  # k rows per slab
        p = lax.axis_index(tree_axis)
        acc = jnp.zeros((ab.shape[0], bb.shape[1]), jnp.float32)
        cur = ab
        for t in range(s):
            # pod-local broadcast step: widen the resident slab shard to
            # the full slab over the column axis
            arow = _collectives.all_gather(cur, axis_y, axis=1, tiled=True)
            j = p ^ t                              # resident slab index
            bslab = lax.dynamic_slice(
                bfull, (j * ks, 0), (ks, bfull.shape[1]))
            acc = acc + local_fn(arow, bslab, out_dtype=jnp.float32)
            if t < s - 1:
                cur = _collectives.ppermute(
                    cur, tree_axis, tree_exchange_perm(s, t))
        return acc.astype(out_dtype)

    return body


def fattree_matmul(a: jax.Array, b: jax.Array, *, mesh,
                   tree_axis: str = "tree",
                   axis_x: str = "x", axis_y: str = "y",
                   out_dtype=None) -> jax.Array:
    """Global (M, K) x (K, N) matmul on a pod-of-pods mesh: the recursive
    fat-tree schedule over ``tree_axis`` with a broadcast (qx, qy) torus
    program inside each pod."""
    from repro.plan import build_plan, execute_plan

    plan = build_plan(
        a.shape[-2], b.shape[-1], a.shape[-1], mesh=mesh, strategy="fattree",
        axes=(tree_axis, axis_x, axis_y), batch=tuple(a.shape[:-2]),
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
    )
    return execute_plan(plan, a, b)
