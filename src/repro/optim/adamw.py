"""AdamW in pure JAX: fp32 master weights + moments, global-norm clipping.

State layout mirrors the param tree, so the same PartitionSpecs shard the
optimizer (optionally further sharded over the data axis, ZeRO-style, via
``zero_specs``).  Params stay bf16 for compute; master weights keep the
fp32 trajectory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params: Any) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def step(
    state: Dict[str, Any], grads: Any, lr: jax.Array, cfg: AdamWConfig
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new state, metrics); derive compute params with
    ``params_from_state``."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    t = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** t.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** t.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w)
        return m, v, w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    new_state = {
        "step": t,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "master": jax.tree.unflatten(treedef, new_w),
    }
    return new_state, {"grad_norm": gnorm, "lr": lr}


def params_from_state(state: Dict[str, Any], like: Any) -> Any:
    return jax.tree.map(
        lambda w, p: w.astype(p.dtype), state["master"], like
    )


def warmup_cosine(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return sched
