"""int8 gradient compression with error feedback for the DP all-reduce.

At 1000+ nodes the data-parallel gradient all-reduce is DCN/ICI-bound; int8
quantization cuts its bytes 4x (bf16 -> int8 + one fp32 scale per tensor).
Error feedback keeps the quantization bias out of the trajectory
(the residual is added back before the next quantization).

``compressed_psum`` is used inside shard_map over the DP axes; the plain
pjit path keeps XLA's native fp32 reduction (default).  This is a
beyond-paper distributed-optimization feature recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis, residual: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """All-reduce mean of ``x`` over ``axis`` in int8, with error feedback.

    Returns (reduced fp32 value, new residual).  Bytes on the wire: 1 per
    element + the scales, vs 4 for the fp32 psum."""
    xf = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(xf)
    deq = dequantize_int8(q, scale)
    new_residual = xf - deq
    # int8 values sum without overflow in int32 across <= 2^23 shards
    summed = lax.psum(q.astype(jnp.int32), axis)
    scale_sum = lax.psum(scale, axis)  # conservative shared-scale estimate
    n = lax.psum(jnp.ones((), jnp.float32), axis)
    # each shard contributed with its own scale; communicate scale-weighted:
    # approximate by the mean scale (exact when scales are equal across DP
    # replicas, which holds after the first steps for averaged gradients).
    mean = summed.astype(jnp.float32) * (scale_sum / n) / n
    return mean, new_residual


def compress_tree_psum(grads: Any, axis, residuals: Any) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = treedef.flatten_up_to(residuals)
    outs, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        o, nr = compressed_psum(g, axis, r)
        outs.append(o.astype(g.dtype))
        new_res.append(nr)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_res)
