from repro.optim import adamw, compress

__all__ = ["adamw", "compress"]
