"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 100 --ckpt /tmp/ckpt

On a real TPU cluster this process runs per host (jax.distributed
initializes from the TPU environment); the mesh comes from
``make_production_mesh`` when the device count allows, else from the
available devices.  Fault tolerance: checkpoints + auto-restore are in the
Trainer; pod-loss re-meshing in repro.runtime.elastic.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, batch_iterator
from repro.models.registry import build_model
from repro.runtime.train import Trainer, TrainConfig


def build_mesh(tp: int):
    devs = jax.devices()
    n = len(devs)
    if n == 1:
        return None
    tp = min(tp, n)
    dp = n // tp
    return jax.make_mesh((dp, tp), ("data", "model"),
                         devices=np.array(devs[: dp * tp]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = build_mesh(args.tp)
    print(f"[launch] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={'1 device' if mesh is None else dict(mesh.shape)}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed)
    tc = TrainConfig(steps=args.steps, lr=args.lr,
                     warmup=max(args.steps // 20, 5),
                     ckpt_dir=args.ckpt, ckpt_every=max(args.steps // 4, 10),
                     log_every=max(args.steps // 20, 1))
    out = Trainer(model, tc, mesh=mesh).fit(
        jax.random.PRNGKey(args.seed), batch_iterator(dc)
    )
    h = out["history"]
    print(f"[launch] done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"({out['restarts']} restarts)")


if __name__ == "__main__":
    main()
