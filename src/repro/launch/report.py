"""Emit EXPERIMENTS.md tables from the dry-run / perf-iteration JSONs.

    PYTHONPATH=src python -m repro.launch.report dryrun_results_v3.json
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def fmt(x, digits=3):
    if x is None:
        return "-"
    return f"{x:.{digits}e}" if (abs(x) < 1e-3 or abs(x) >= 1e4) else f"{x:.{digits}f}"


def roofline_table(cells, mesh_filter="16x16"):
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "peak GiB | MODEL_FLOPS | useful frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c["mesh"] != mesh_filter or not c.get("ok"):
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
            f"{r['dominant']} | {fmt_bytes(c['memory']['peak_bytes'])} | "
            f"{fmt(r['model_flops'])} | {fmt(r.get('useful_flops_fraction'))} | "
            f"{fmt(r.get('roofline_fraction'), 4)} |"
        )
    return "\n".join(rows)


def dryrun_table(cells):
    rows = [
        "| arch | shape | mesh | compile s | peak GiB/dev | fits 16G | "
        "coll bytes/dev | AG | AR | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if not c.get("ok"):
            continue
        r = c["roofline"]
        k = r["coll_by_kind"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['compile_s']} | "
            f"{fmt_bytes(c['memory']['peak_bytes'])} | "
            f"{'Y' if c['memory'].get('fits_hbm_16g') else 'N'} | "
            f"{fmt(r['collective_bytes_per_chip'])} | "
            f"{fmt(k.get('all-gather'))} | {fmt(k.get('all-reduce'))} | "
            f"{fmt(k.get('reduce-scatter'))} | {fmt(k.get('all-to-all'))} | "
            f"{fmt(k.get('collective-permute'))} |"
        )
    return "\n".join(rows)


def plan_cache_table(info=None):
    """One-row table over ``repro.plan.cache_info()`` (live process counters
    unless a captured ``info`` dict -- e.g. from a metrics JSON -- is given)."""
    if info is None:
        from repro.plan import cache_info
        info = cache_info()
    hits, misses = info["hits"], info["misses"]
    total = hits + misses
    rate = f"{hits / total:.2f}" if total else "-"
    return "\n".join([
        "| hits | misses | hit rate | currsize | maxsize | evictions |",
        "|---|---|---|---|---|---|",
        f"| {hits} | {misses} | {rate} | {info['currsize']} | "
        f"{info['maxsize']} | {info['evictions']} |",
    ])


def serve_sweep_table(data):
    """Render a ``repro.serve_sweep/v1`` JSON (benchmarks/serve_sweep.py)
    as a markdown table.  Latency quantiles can be null (a 1-token run has
    no timed decode steps) and print as '-'; failed cells print their last
    error line."""

    def v(x):
        if x is None:
            return "-"
        return f"{x:.3f}" if isinstance(x, float) else str(x)

    rows = [
        "| mesh | bucket | strategy | routed | tok/s | tok/s/dev | "
        "ttft ms | p50 ms | p99 ms | hit rate | match |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in data["cells"]:
        if not c.get("ok"):
            err = (c.get("error") or "?").strip().splitlines()[-1][:60]
            rows.append(f"| {c['mesh']} | {c['bucket']} | {c['strategy']} | "
                        f"ERR | - | - | - | - | - | - | {err} |")
            continue
        rows.append(
            f"| {c['mesh']} | {c['bucket']} | {c['strategy']} | "
            f"{'Y' if c['routed'] else 'n'} | {v(c['tokens_per_s'])} | "
            f"{v(c['tokens_per_s_per_device'])} | {v(c['ttft_ms'])} | "
            f"{v(c['p50_ms'])} | {v(c['p99_ms'])} | "
            f"{v(c['cache_hit_rate'])} | "
            f"{'Y' if c['match_baseline'] else 'MISMATCH'} |")
    return "\n".join(rows)


def kernel_metrics_table(metrics):
    """Kernel-side health rows from an ``obs.write_metrics`` snapshot:
    per-call microseconds, roofline fraction, the ragged-shape padding
    waste ratio (padded/useful FLOPs; 1.0 = no waste), and autotune
    candidate timings when a search ran in-process."""
    names = ("kernel.matmul.us", "kernel.matmul.roofline_fraction",
             "kernel.pad_waste", "tune.candidate_us")
    rows = [
        "| metric | n | mean | min | max |",
        "|---|---|---|---|---|",
    ]
    found = False
    for name in names:
        v = metrics.get(name)
        if not isinstance(v, dict):
            continue
        found = True
        rows.append(f"| {name} | {v['count']} | {fmt(v['mean'])} | "
                    f"{fmt(v['min'])} | {fmt(v['max'])} |")
    if not found:
        rows.append("| (no kernel metrics recorded) | - | - | - | - |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results_v2.json"
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") == "repro.serve_sweep/v1":
        cfg = data["config"]
        print(f"### Serve sweep: {data['arch']} "
              f"(max_new={cfg['max_new_tokens']}, "
              f"{cfg['devices']} devices)\n")
        print(serve_sweep_table(data))
        return
    if "metrics" in data and "cells" not in data:
        # an obs.write_metrics snapshot (e.g. bench_metrics.json)
        print(f"### Kernel metrics (schema {data.get('schema', '?')})\n")
        print(kernel_metrics_table(data["metrics"]))
        return
    cells = data["cells"]
    print("### Roofline (single-pod 16x16)\n")
    print(roofline_table(cells, "16x16"))
    print("\n### Dry-run record (both meshes)\n")
    print(dryrun_table(cells))
    print("\n### Skipped cells\n")
    for arch, shape, why in data.get("skipped", []):
        print(f"* {arch} x {shape}: {why}")
    print("\n### Plan cache\n")
    print(plan_cache_table(data.get("plan_cache")))


if __name__ == "__main__":
    main()
