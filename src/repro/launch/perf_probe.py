"""Performance probes: link calibration (library) + the perf-iteration cell
probe (CLI).

**Library entry point** -- ``probe_links(mesh) -> MachineProfile`` runs the
``repro.obs.calibrate`` microbenchmarks (ring ppermutes per mesh axis,
jit'd matmul peak) and returns the fitted α–β machine profile the planner
consumes via ``build_plan(profile=...)``.  Importing this module is
side-effect free (no env mutation, no jax init).

**CLI** -- the default ``__main__`` mode calibrates and writes the
machine-profile JSON:

    PYTHONPATH=src python -m repro.launch.perf_probe \
        --profile-out machine_profile.json --devices 8 --mesh-shape 2x2

``--tune`` additionally runs the measured kernel autotune search
(``repro.tune``) over ``--tune-shapes`` and embeds the resulting
``TuningTable`` in the profile (and, with ``--tune-out``, as its own
artifact) -- one probe run yields both calibration halves: fitted α–β
links for the comm side and measured kernel seconds for the compute side
of ``calibrated_total_s``.

The legacy perf-iteration mode (lower ONE arch x shape cell with config
overrides and print the roofline terms; the Sec.-Perf hillclimb driver)
is selected by ``--arch``:

    PYTHONPATH=src python -m repro.launch.perf_probe \
        --arch granite-20b --shape train_4k \
        --set remat=none attn_probs_dtype=bf16 --no-zero --tag it3

Overrides apply dataclasses.replace on the arch config; measurement always
uses the final analyzer (invariant-aware by default; --naive-analyzer for
the pessimistic count).  Appends a JSON record to perf_iterations.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.obs.calibrate import probe_links  # noqa: F401  (library API)
from repro.obs.profile import MachineProfile, save_profile  # noqa: F401


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def _parse_mesh_shape(spec: str):
    return tuple(int(s) for s in spec.lower().split("x") if s)


def calibrate_main(args) -> None:
    """Default mode: probe the links, write the machine-profile JSON."""
    if args.devices > 1 and "host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"{os.environ.get('XLA_FLAGS', '')} "
            f"--xla_force_host_platform_device_count={args.devices}").strip()
    import jax
    import numpy as np

    mesh = None
    devs = np.array(jax.devices())
    if args.mesh_shape and len(devs) > 1:
        shape = _parse_mesh_shape(args.mesh_shape)
        names = ("x", "y", "z")[:len(shape)] if len(shape) > 1 else ("t",)
        import math

        mesh = jax.make_mesh(shape, names,
                             devices=devs[:math.prod(shape)])
    tree_axes = tuple(a for a in args.tree_axes.split(",") if a)
    profile = probe_links(mesh, reps=args.reps, tree_axes=tree_axes)
    if args.tune:
        import dataclasses

        from repro.tune import Tuner, save_table

        tuner = Tuner(reps=args.tune_reps,
                      max_candidates=args.tune_candidates or None)
        for spec in args.tune_shapes.split(","):
            if not spec:
                continue
            tm, tn, tk = _parse_mesh_shape(spec)
            tuner.entry_for(tm, tn, tk, dtype=args.tune_dtype)
        table = tuner.table()
        profile = dataclasses.replace(profile, tuning=table)
        if args.tune_out:
            save_table(table, args.tune_out)
            print(f"# wrote {args.tune_out}")
    save_profile(profile, args.profile_out)
    print(json.dumps(profile.to_json(), indent=1, sort_keys=True))
    print(f"# wrote {args.profile_out}")


def cell_probe_main(args) -> None:
    """Legacy perf-iteration mode (``--arch``): one cell, roofline terms."""
    # must precede jax init: the cell probe needs a forced device farm
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    from repro.configs import canonical
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    overrides = dict(parse_override(kv) for kv in args.set)

    # monkey-patch get_config so lower_cell sees the overridden config
    import dataclasses

    import repro.launch.dryrun as dr
    base_get = dr.get_config

    def patched(name):
        cfg = base_get(name)
        return dataclasses.replace(cfg, **overrides) if overrides else cfg

    dr.get_config = patched

    if args.naive_analyzer:
        import repro.roofline.hlo_stats as hs
        orig = hs.analyze
        hs.analyze = lambda text, invariant_aware=True: orig(text, False)

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    t0 = time.perf_counter()
    rec = lower_cell(canonical(args.arch), args.shape, mesh,
                     remat=args.remat, zero=not args.no_zero)
    rec.update(tag=args.tag, overrides=overrides, zero=not args.no_zero,
               remat=args.remat, analyzer="naive" if args.naive_analyzer
               else "invariant-aware", wall_s=round(time.perf_counter() - t0, 1))
    r = rec["roofline"]
    print(json.dumps({
        "tag": args.tag, "arch": rec["arch"], "shape": rec["shape"],
        "dominant": r["dominant"],
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "step_bound_s": r["step_s_bound"],
        "roofline_fraction": r["roofline_fraction"],
        "coll_by_kind": r["coll_by_kind"],
        "peak_GiB": round((rec["memory"]["peak_bytes"] or 0) / 2**30, 2),
    }, indent=1))
    hist = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            hist = json.load(f)
    hist.append(rec)
    with open(args.out, "w") as f:
        json.dump(hist, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    # calibration mode (default)
    ap.add_argument("--profile-out", default="machine_profile.json")
    ap.add_argument("--devices", type=int, default=1,
                    help="forced host device count for CPU calibration")
    ap.add_argument("--mesh-shape", default="",
                    help="e.g. 2x2 or 8 -- mesh to probe axes on")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tree-axes", default="",
                    help="comma-separated inter-pod (DCN-class) mesh axes; "
                         "pooled into a 'dcn' link class instead of 'ici'")
    ap.add_argument("--tune", action="store_true",
                    help="also run the kernel autotune search and embed "
                         "the TuningTable in the profile")
    ap.add_argument("--tune-shapes", default="256x256x256,384x128x256",
                    help="comma-separated MxNxK shapes to tune")
    ap.add_argument("--tune-reps", type=int, default=3)
    ap.add_argument("--tune-candidates", type=int, default=8,
                    help="bound the per-shape candidate search (0 = full)")
    ap.add_argument("--tune-dtype", default="float32")
    ap.add_argument("--tune-out", default="",
                    help="also write the TuningTable as its own JSON")
    # legacy cell-probe mode (selected by --arch)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--set", nargs="*", default=[], metavar="key=val")
    ap.add_argument("--remat", default="config")
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--naive-analyzer", action="store_true")
    ap.add_argument("--tag", default="probe")
    ap.add_argument("--out", default="perf_iterations.json")
    args = ap.parse_args()

    if args.arch is not None:
        if args.shape is None:
            ap.error("--arch requires --shape")
        cell_probe_main(args)
    else:
        calibrate_main(args)


if __name__ == "__main__":
    main()
