import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede all other imports (jax locks device count on first init).

"""Perf-iteration probe: lower ONE cell with config overrides and print the
three roofline terms + per-kind collective bytes.  The Sec.-Perf hillclimb
driver: each hypothesis -> change -> measure cycle is one invocation.

    PYTHONPATH=src python -m repro.launch.perf_probe \
        --arch granite-20b --shape train_4k \
        --set remat=none attn_probs_dtype=bf16 --no-zero --tag it3

Overrides apply dataclasses.replace on the arch config; measurement always
uses the final analyzer (invariant-aware by default; --naive-analyzer for
the pessimistic count).  Appends a JSON record to perf_iterations.json.
"""
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import canonical, get_config
from repro.launch.dryrun import lower_cell, _batch_shardings, _rep  # noqa
from repro.launch.mesh import make_production_mesh


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--set", nargs="*", default=[], metavar="key=val")
    ap.add_argument("--remat", default="config")
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--naive-analyzer", action="store_true")
    ap.add_argument("--tag", default="probe")
    ap.add_argument("--out", default="perf_iterations.json")
    args = ap.parse_args()

    overrides = dict(parse_override(kv) for kv in args.set)

    # monkey-patch get_config so lower_cell sees the overridden config
    import repro.launch.dryrun as dr
    base_get = dr.get_config

    def patched(name):
        cfg = base_get(name)
        return dataclasses.replace(cfg, **overrides) if overrides else cfg

    dr.get_config = patched

    if args.naive_analyzer:
        import repro.roofline.hlo_stats as hs
        orig = hs.analyze
        hs.analyze = lambda text, invariant_aware=True: orig(text, False)

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    t0 = time.perf_counter()
    rec = lower_cell(canonical(args.arch), args.shape, mesh,
                     remat=args.remat, zero=not args.no_zero)
    rec.update(tag=args.tag, overrides=overrides, zero=not args.no_zero,
               remat=args.remat, analyzer="naive" if args.naive_analyzer
               else "invariant-aware", wall_s=round(time.perf_counter() - t0, 1))
    r = rec["roofline"]
    print(json.dumps({
        "tag": args.tag, "arch": rec["arch"], "shape": rec["shape"],
        "dominant": r["dominant"],
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "step_bound_s": r["step_s_bound"],
        "roofline_fraction": r["roofline_fraction"],
        "coll_by_kind": r["coll_by_kind"],
        "peak_GiB": round((rec["memory"]["peak_bytes"] or 0) / 2**30, 2),
    }, indent=1))
    hist = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            hist = json.load(f)
    hist.append(rec)
    with open(args.out, "w") as f:
        json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
