"""Production serving launcher: plan-routed batched decode via repro.serve.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --mesh 2x2 --buckets 4x16 8x32 --max-new 16

Builds a ``repro.serve.Server`` (persistent compiled prefill/decode pair),
AOT-warms the declared (batch, seq) bucket grid -- filling the plan cache
with each bucket's ``SchedulePlan``s -- then serves a synthetic request
batch through the bucket router and prints throughput, TTFT, per-token
latency quantiles, and the serve-window plan-cache report.  ``--mesh``
routes every forward matmul through the plan engine (on CPU runs set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first, as the CI
smoke job does); without it the server decodes the local GSPMD baseline.
``--smoke`` selects the reduced config and exits nonzero on any serving
error -- the CI entry point.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.report import plan_cache_table
from repro.models.registry import build_model
from repro.runtime.serve import ServeConfig
from repro.serve import Server, as_bucket


def _parse_mesh(spec):
    if not spec:
        return None
    rows, cols = (int(s) for s in spec.lower().split("x"))
    devs = jax.devices()
    if len(devs) < rows * cols:
        raise SystemExit(
            f"--mesh {spec} needs {rows * cols} devices, have {len(devs)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=N for "
            f"CPU runs")
    return jax.make_mesh((rows, cols), ("x", "y"), devices=devs[: rows * cols])


def _parse_bucket(spec) -> tuple:
    batch, seq = (int(s) for s in spec.lower().split("x"))
    return (batch, seq)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="RxC",
                    help="route matmuls through the plan engine on this mesh")
    ap.add_argument("--strategy", default=None,
                    help="pin the schedule strategy inside the plan scope")
    ap.add_argument("--buckets", nargs="+", default=["4x16", "8x32"],
                    metavar="BxS", help="warm (batch, seq) serving buckets")
    ap.add_argument("--batch", type=int, default=4,
                    help="synthetic requests to serve")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    mesh = _parse_mesh(args.mesh)
    buckets = [as_bucket(_parse_bucket(b)) for b in args.buckets]
    sc = ServeConfig(max_new_tokens=args.max_new, max_seq=args.max_seq,
                     temperature=args.temperature)

    server = Server(model, params, sc, mesh=mesh, strategy=args.strategy,
                    buckets=buckets)
    warm = server.warmup()
    for label, w in warm.items():
        print(f"[warmup] bucket {label}: {w['plans']} plans, "
              f"{w['warm_s']:.2f}s")

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=rng.integers(4, 12)).tolist()
               for _ in range(args.batch)]
    res = server.generate(prompts, key=jax.random.PRNGKey(args.seed))
    q = res.latency_quantiles_ms()
    routed = "plan-routed" if mesh is not None else "local"
    print(f"[serve] arch={cfg.name} {routed} batch={args.batch} "
          f"bucket={res.bucket or 'cold'} "
          f"{res.generated_tokens} tokens in {res.wall_s:.2f}s "
          f"({res.tokens_per_s:.1f} tok/s) ttft={res.ttft_s * 1e3:.1f}ms "
          f"p50={q['p50_ms'] if q['p50_ms'] is None else round(q['p50_ms'], 2)}ms "
          f"p99={q['p99_ms'] if q['p99_ms'] is None else round(q['p99_ms'], 2)}ms")
    for i, toks in enumerate(res.new_tokens):
        print(f"  req{i} (len {len(res.sequences[i]) - len(toks)}): "
              f"{toks[:8]}...")

    rep = server.cache_report()
    print("\n### Plan cache\n")
    print(plan_cache_table(rep["info"]))
    sw = rep.get("serve_window")
    if sw is not None:
        rate = "-" if sw["hit_rate"] is None else f"{sw['hit_rate']:.2f}"
        print(f"serve window: {sw['hits']} hits / {sw['misses']} misses "
              f"(hit rate {rate})")
        if mesh is not None and sw["hit_rate"] not in (None, 1.0):
            print("[serve] ERROR: warm-bucket serving missed the plan cache")
            return 1
    if mesh is not None and res.plan_probe["probed"] == 0:
        print("[serve] ERROR: no warm plans probed -- decode not plan-routed")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
