"""Production serving launcher: batched decode over the KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.registry import build_model
from repro.runtime.serve import ServeConfig, batch_requests, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
               for _ in range(args.batch)]
    batch, lens = batch_requests(prompts)
    sc = ServeConfig(max_new_tokens=args.max_new, max_seq=args.max_seq,
                     temperature=args.temperature)
    t0 = time.perf_counter()
    out = generate(model, params, batch, sc)
    dt = time.perf_counter() - t0
    total_new = args.max_new * args.batch
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"{total_new} tokens in {dt:.2f}s ({total_new/dt:.1f} tok/s)")
    for i, row in enumerate(out):
        print(f"  req{i} (len {lens[i]}): ...{row[-args.max_new:].tolist()[:8]}...")


if __name__ == "__main__":
    main()
