"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape)`` returns the abstract batch for the given
shape cell; ``abstract_state``/``abstract_cache`` build the abstract
parameter/optimizer/cache trees via eval_shape.  Audio/VLM frontends are
stubs: seamless gets precomputed frame embeddings, chameleon gets
interleaved text+VQ token ids (early fusion shares the vocabulary).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models.config import ModelConfig
from repro.models.registry import build_model

SRC_FRAMES_32K = 4096   # seamless encoder frames for the prefill/train cells


def input_specs(arch: str, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    if cell.kind == "train":
        batch = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.family == "audio":
            batch["src_embed"] = jax.ShapeDtypeStruct(
                (b, min(s, SRC_FRAMES_32K), cfg.d_model), jnp.bfloat16
            )
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": tok(b, s)}
        if cfg.family == "audio":
            batch["src_embed"] = jax.ShapeDtypeStruct(
                (b, min(s, SRC_FRAMES_32K), cfg.d_model), jnp.bfloat16
            )
        return batch
    if cell.kind == "decode":
        return {"tokens": tok(b, 1), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(cell.kind)


def abstract_params(cfg: ModelConfig):
    model = build_model(cfg)
    return model, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def abstract_cache(model, cfg: ModelConfig, shape: str):
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    if cfg.family == "audio":
        return jax.eval_shape(
            lambda: model.init_cache(b, s, src_len=SRC_FRAMES_32K)
        )
    return jax.eval_shape(lambda: model.init_cache(b, s))


def abstract_opt_state(params):
    from repro.optim import adamw
    return jax.eval_shape(adamw.init, params)
