import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/roofline analysis.

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single,multi
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json

For each cell this lowers the *production* step function:
  train_4k     -> full train_step (fwd + bwd + AdamW update, donated state)
  prefill_32k  -> forward logits
  decode_32k / long_500k -> serve_step (one token against the KV/state cache)

and requires ``.lower().compile()`` to succeed on the 16x16 single-pod mesh
AND the 2x16x16 multi-pod mesh.  memory_analysis() proves fit;
cost_analysis() + the HLO call-graph analyzer feed Sec. Roofline.

(note: no ``from __future__`` here -- the XLA_FLAGS lines above must stay
the first statements of the module.)
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, runnable_cells, skipped_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_opt_state,
                                abstract_params, input_specs)
from repro.models.sharding_rules import (cache_shardings, param_shardings,
                                         zero_shardings)
from repro.optim import adamw
from repro.roofline import analysis
from repro.runtime.sharding import resolve_axis, use_mesh


def _batch_shardings(batch, mesh: Mesh, *, shard_batch: bool):
    baxes = resolve_axis("batch", mesh)
    out = {}
    for k, v in batch.items():
        if k == "pos" or v.ndim == 0 or not shard_batch:
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, P(baxes, *([None] * (v.ndim - 1))))
    return out


def _rep(mesh):
    return NamedSharding(mesh, P())


def lower_cell(arch: str, shape: str, mesh: Mesh, *,
               remat: str = "config", zero: bool = True) -> Dict:
    cfg = get_config(arch)
    if remat != "config":
        cfg = dataclasses.replace(cfg, remat=remat)
    model, aparams = abstract_params(cfg)
    cell = SHAPES[shape]
    chips = mesh.size
    psh = param_shardings(aparams, mesh)
    batch = input_specs(arch, shape)
    shard_batch = cell.global_batch >= mesh.shape.get("data", 1)
    bsh = _batch_shardings(batch, mesh, shard_batch=shard_batch)
    dtypes = jax.tree.map(lambda p: p.dtype, aparams)

    if cell.kind == "train":
        astate = abstract_opt_state(aparams)
        osh = zero_shardings(aparams, mesh) if zero else psh
        sh_state = {
            "step": _rep(mesh), "master": osh, "m": osh, "v": osh,
        }
        opt_cfg = adamw.AdamWConfig()

        def train_step(state, batch):
            def loss_of_master(master):
                params = jax.tree.map(lambda w, t: w.astype(t), master, dtypes)
                return model.loss(params, batch)
            (loss, _), grads = jax.value_and_grad(
                loss_of_master, has_aux=True
            )(state["master"])
            new_state, _ = adamw.step(state, grads, jnp.float32(1e-4), opt_cfg)
            return new_state, loss

        fn = jax.jit(
            train_step,
            in_shardings=(sh_state, bsh),
            out_shardings=(sh_state, _rep(mesh)),
            donate_argnums=(0,),
        )
        args = ({"step": jax.ShapeDtypeStruct((), jnp.int32),
                 **{k: astate[k] for k in ("master", "m", "v")}}, batch)
        tokens = cell.global_batch * cell.seq_len
        model_flops = analysis.train_model_flops(cfg.active_param_count(), tokens)
    elif cell.kind == "prefill":
        def prefill(params, batch):
            if cfg.family == "audio":
                logits, _ = model.forward(params, {
                    "tokens": batch["tokens"], "src_embed": batch["src_embed"]})
            else:
                logits, _ = model.forward(params, batch["tokens"])
            return logits
        model_ax = resolve_axis("model", mesh)
        from repro.layers.embed import padded_vocab
        if padded_vocab(cfg.vocab_size) % mesh.shape.get("model", 1) != 0:
            model_ax = None
        fn = jax.jit(
            prefill, in_shardings=(psh, bsh),
            out_shardings=NamedSharding(
                mesh, P(resolve_axis("batch", mesh), None, model_ax)),
        )
        args = (aparams, batch)
        tokens = cell.global_batch * cell.seq_len
        model_flops = analysis.infer_model_flops(cfg.active_param_count(), tokens)
    else:  # decode
        acache = abstract_cache(model, cfg, shape)
        csh = cache_shardings(acache, mesh, shard_batch=shard_batch)

        def serve_step(params, cache, batch):
            return model.decode_step(params, cache, batch["tokens"], batch["pos"])

        fn = jax.jit(
            serve_step,
            in_shardings=(psh, csh, bsh),
            out_shardings=(None, csh),
            donate_argnums=(1,),
        )
        args = (aparams, acache, batch)
        tokens = cell.global_batch  # one token per sequence
        model_flops = analysis.infer_model_flops(cfg.active_param_count(), tokens)

    t0 = time.perf_counter()
    with use_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = analysis.from_compiled(compiled, chips=chips, model_flops=model_flops)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {  # per-device bytes (XLA compiles the per-device module)
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "fits_hbm_16g": bool(
                (getattr(mem, "peak_memory_in_bytes", 0) or 0) < 16 * 2 ** 30
            ),
        },
        "roofline": roof.summary(),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--remat", default="config",
                    help="override remat policy: config|none|dots|full")
    ap.add_argument("--no-zero", action="store_true",
                    help="disable ZeRO-1 optimizer-state sharding")
    args = ap.parse_args()

    meshes = {}
    if "single" in args.mesh:
        meshes["single"] = make_production_mesh(multi_pod=False)
    if "multi" in args.mesh:
        meshes["multi"] = make_production_mesh(multi_pod=True)

    cells = runnable_cells()
    if args.arch:
        from repro.configs import canonical
        cells = [c for c in cells if c[0] == canonical(args.arch)]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f).get("cells", [])
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    for arch, shape in cells:
        for mesh_name, mesh in meshes.items():
            mesh_id = "x".join(str(s) for s in mesh.devices.shape)
            if (arch, shape, mesh_id) in done:
                continue
            print(f"[dryrun] {arch} x {shape} on {mesh_id} ...", flush=True)
            try:
                rec = lower_cell(arch, shape, mesh, remat=args.remat,
                                 zero=not args.no_zero)
                rec["ok"] = True
                r = rec["roofline"]
                peak = rec["memory"]["peak_bytes"] or 0
                print(
                    f"  ok: compile {rec['compile_s']:.1f}s  "
                    f"dominant={r['dominant']}  "
                    f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                    f"coll={r['collective_s']:.3e}s  "
                    f"peak={peak/2**30:.2f}GiB",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 -- record and continue
                rec = {"arch": arch, "shape": shape, "mesh": mesh_id,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {type(e).__name__}: {str(e)[:200]}", flush=True)
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump({"cells": results,
                           "skipped": skipped_cells()}, f, indent=1)

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled; skips documented: "
          f"{len(skipped_cells())}")


if __name__ == "__main__":
    main()
