"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e full pod); 2 pods = 512 chips when
    multi_pod.  Axes: (pod,) data, model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
