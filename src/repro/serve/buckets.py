"""(batch, seq) bucket grid + router for the serving harness.

Serving cost has two compile-relevant shapes: the prefill token block
(B, S_prompt) and the decode step (B, 1).  Warmup AOT-compiles (and
plan-caches) one program pair per declared bucket; the router then snaps
every incoming request batch to the smallest warm bucket -- requests are
left-padded to ``bucket.seq`` and the batch is padded with dummy rows to
``bucket.batch`` -- so no request ever pays planning or compile cost.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One warm serving shape: ``batch`` requests x ``seq`` prompt slots."""

    batch: int
    seq: int

    def __post_init__(self):
        if self.batch < 1 or self.seq < 1:
            raise ValueError(f"bucket sides must be >= 1, got {self}")

    @property
    def label(self) -> str:
        return f"{self.batch}x{self.seq}"


def as_bucket(b) -> Bucket:
    if isinstance(b, Bucket):
        return b
    batch, seq = b
    return Bucket(int(batch), int(seq))


def bucket_grid(batches: Iterable[int], seqs: Iterable[int]) -> Tuple[Bucket, ...]:
    """The full batches x seqs grid, sorted ascending (batch, then seq)."""
    return tuple(sorted(Bucket(int(b), int(s))
                        for b in set(batches) for s in set(seqs)))


def route(n_requests: int, max_prompt_len: int,
          buckets: Sequence[Bucket]) -> Optional[Bucket]:
    """The cheapest warm bucket fitting ``n_requests`` prompts of length
    <= ``max_prompt_len``: smallest padded token area (batch * seq), ties
    to the smaller batch.  None when nothing fits (the caller serves the
    exact shape cold and should count it)."""
    fitting = [b for b in buckets
               if b.batch >= n_requests and b.seq >= max_prompt_len]
    if not fitting:
        return None
    return min(fitting, key=lambda b: (b.batch * b.seq, b.batch, b.seq))
