"""Plan-routed serving server: persistent compiled steps, bucket routing,
AOT plan-cache warmup, latency accounting.

The seed-era ``runtime.serve.generate`` builds a fresh ``jax.jit`` wrapper
per call, so every request pays trace + compile + planning.  ``Server``
holds ONE jitted prefill and ONE jitted decode step for the lifetime of
the process and AOT-warms them over a declared (batch, seq) bucket grid:

  * ``warmup()`` runs a dummy prefill + decode step per bucket inside the
    ``planned_matmuls(mesh)`` scope.  Tracing routes every layer matmul
    through ``repro.plan.build_plan``, so the plan cache fills with each
    bucket's ``SchedulePlan``s and XLA compiles the bucket's program pair.
    The plans inserted per bucket are snapshotted (key -> plan).
  * ``generate()`` routes the request batch to the nearest warm bucket
    (left-padding prompts to ``bucket.seq`` with per-row position offsets,
    padding the batch with dummy rows to ``bucket.batch``), re-``get``s the
    bucket's plan keys from the cache -- all hits after warmup; an evicted
    plan is re-pinned from the snapshot -- and decodes with the warm
    compiled functions.  Per-token wall latencies and TTFT are measured
    around the blocking device calls.

Observability: ``serve.prefill`` / ``serve.decode_step`` spans,
``serve.ttft_us`` / ``serve.decode_token_us`` histograms, and
``serve.requests`` / ``serve.tokens`` / ``serve.cold_bucket`` /
``serve.plan_repin`` counters (all guarded on ``obs.enabled()``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.plan.cache import plan_cache
from repro.runtime.serve import (ServeConfig, _default_prefill, _default_step,
                                 _sample, batch_requests, planned_scope)

from .buckets import Bucket, as_bucket, route

DEFAULT_BUCKETS = ((4, 16), (4, 32), (8, 16), (8, 32))


@dataclasses.dataclass
class ServeResult:
    """One served batch: per-request token sequences + latency breakdown."""

    sequences: List[List[int]]        # prompt + generated, padding stripped
    new_tokens: List[List[int]]       # generated suffix per request
    bucket: Optional[str]             # routed bucket label, None = cold
    ttft_s: float                     # prefill + first sampled token
    step_latencies_s: np.ndarray      # per-token decode latency (after 1st)
    wall_s: float
    plan_probe: Dict[str, int]        # warm-plan cache probe accounting

    @property
    def generated_tokens(self) -> int:
        return sum(len(t) for t in self.new_tokens)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s > 0 else 0.0

    def latency_quantiles_ms(self) -> Dict[str, Optional[float]]:
        """p50/p99 per-token decode latency in ms; None when fewer than one
        timed step was taken (max_new_tokens <= 1 -- the sweep report
        renders these as '-')."""
        if self.step_latencies_s.size == 0:
            return {"p50_ms": None, "p99_ms": None}
        return {
            "p50_ms": float(np.percentile(self.step_latencies_s, 50) * 1e3),
            "p99_ms": float(np.percentile(self.step_latencies_s, 99) * 1e3),
        }


class Server:
    """Production serving harness over one model + mesh (see module doc).

    ``mesh=None`` serves the local (unrouted) baseline path -- same
    bucketing and warmup, no plan engine -- which the sweep harness uses
    as the bitwise-comparison baseline for plan-routed decode.
    """

    def __init__(self, model, params, cfg: ServeConfig, *, mesh=None,
                 strategy: Optional[str] = None,
                 tuning=None,
                 buckets: Sequence = DEFAULT_BUCKETS,
                 pad_id: int = 0, dummy_token: int = 1):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.mesh = mesh
        self.strategy = strategy
        self.tuning = tuning
        self.pad_id = pad_id
        self.dummy_token = dummy_token
        self.buckets: Tuple[Bucket, ...] = tuple(
            sorted(as_bucket(b) for b in buckets))
        for b in self.buckets:
            cfg.validate_prompt_len(b.seq)
        self._uses_offsets = bool(
            getattr(model, "supports_position_offsets", False))
        # ONE persistent compiled function pair for the server's lifetime;
        # the plan scope lives INSIDE the jitted closure so this server's
        # trace-cache entries are its own (see runtime.serve._default_*)
        self._prefill = _default_prefill(model, mesh, strategy, tuning)
        self._step = _default_step(model, mesh, strategy, tuning)
        # per-bucket plan snapshot: key -> SchedulePlan inserted by warmup
        self._bucket_plans: Dict[Bucket, Dict] = {}
        # per-bucket tuning keys the warmup searches populated (a live
        # Tuner tunes each bucket's local kernel shapes at trace time)
        self._bucket_tune_keys: Dict[Bucket, Tuple] = {}
        self._warm_cache_info: Optional[Dict[str, int]] = None
        self._warm_tune_stats: Optional[Dict[str, int]] = None

    # -- warmup --------------------------------------------------------------

    def warmup(self, buckets: Optional[Sequence] = None) -> Dict:
        """AOT-warm every bucket: compile the (prefill, step) program pair
        and populate the plan cache with the bucket's ``SchedulePlan``s.
        Returns per-bucket accounting ``{label: {plans, warm_s}}``; after
        this, requests routed to a warm bucket pay zero planning/compile
        cost and ``cache_report()`` pins the serve-window hit rate."""
        buckets = self.buckets if buckets is None else tuple(
            as_bucket(b) for b in buckets)
        report: Dict[str, Dict] = {}
        for bucket in buckets:
            t0 = time.perf_counter()
            n_plans = self._warm_bucket(bucket)
            report[bucket.label] = {
                "plans": n_plans,
                "warm_s": time.perf_counter() - t0,
            }
        if obs.enabled():
            obs.counter("serve.warmup.buckets").inc(len(buckets))
        self._warm_cache_info = plan_cache.info()
        if self.tuning is not None and hasattr(self.tuning, "stats"):
            self._warm_tune_stats = dict(self.tuning.stats)
        return report

    def _warm_bucket(self, bucket: Bucket) -> int:
        """Trace/compile one bucket's programs; snapshot the plan-cache
        entries it inserted so the router can probe (and re-pin) them."""
        before = set(plan_cache.keys())
        tune_before = (set(self.tuning.keys())
                       if self.tuning is not None
                       and hasattr(self.tuning, "keys") else set())
        toks = jnp.full((bucket.batch, bucket.seq), self.dummy_token,
                        jnp.int32)
        cache = self.model.init_cache(bucket.batch, self.cfg.max_seq)
        offsets = (jnp.zeros((bucket.batch,), jnp.int32)
                   if self._uses_offsets else None)
        key = jax.random.PRNGKey(0)
        with planned_scope(self.mesh, self.strategy, self.tuning):
            with obs.span("serve.warmup", bucket=bucket.label):
                logits, cache = self._call_prefill(cache, toks, offsets)
                # two steps, not one: step 2's inputs carry the shardings
                # step 1's outputs committed them to, a different jit
                # signature than the fresh init_cache warmup step -- one
                # step would leave serving to compile that steady state
                # mid-decode
                for i in range(min(2, self.cfg.max_new_tokens)):
                    cur = _sample(logits, self.cfg, key)
                    logits, cache = self._call_step(
                        cache, cur[:, None], jnp.int32(bucket.seq + i),
                        offsets)
                jax.block_until_ready(logits)
        new_keys = [k for k in plan_cache.keys() if k not in before]
        snapshot = {k: plan_cache.get(k) for k in new_keys}
        # a later bucket can share plans with an earlier one (same decode
        # batch): extend instead of replace so probes cover the union
        self._bucket_plans.setdefault(bucket, {}).update(snapshot)
        if self.tuning is not None and hasattr(self.tuning, "keys"):
            new_tune = tuple(k for k in self.tuning.keys()
                             if k not in tune_before)
            prev = self._bucket_tune_keys.get(bucket, ())
            self._bucket_tune_keys[bucket] = prev + tuple(
                k for k in new_tune if k not in prev)
        return len(new_keys)

    # -- serving -------------------------------------------------------------

    def generate(self, prompt_list: Sequence[Sequence[int]],
                 key: Optional[jax.Array] = None) -> ServeResult:
        """Serve one request batch: route to the nearest warm bucket, pad,
        decode, strip padding, return per-request sequences + latencies."""
        if not prompt_list:
            return ServeResult([], [], None, 0.0, np.zeros(0), 0.0,
                               {"probed": 0, "missing": 0})
        t_start = time.perf_counter()
        n = len(prompt_list)
        maxlen = max(len(p) for p in prompt_list)
        bucket = route(n, maxlen, self.buckets)
        if bucket is not None and not self._uses_offsets \
                and bucket.seq != maxlen:
            # seq-padding shifts tokens through a recurrent state; only
            # batch-pad for models without position-offset support
            bucket = Bucket(bucket.batch, maxlen) \
                if bucket.batch >= n else None
        probe = self._probe_bucket(bucket)

        if bucket is None:
            if obs.enabled():
                obs.counter("serve.cold_bucket").inc()
            batch, lens = batch_requests(prompt_list, self.pad_id)
            b_rows = n
        else:
            dummies = [[self.dummy_token]] * (bucket.batch - n)
            batch, lens = batch_requests(
                list(prompt_list) + dummies, self.pad_id, pad_to=bucket.seq)
            b_rows = bucket.batch
        self.cfg.validate_prompt_len(batch.shape[1])

        key = key if key is not None else jax.random.PRNGKey(0)
        tokens = jnp.asarray(batch, jnp.int32)
        sp = tokens.shape[1]
        offsets = (jnp.asarray(sp - lens, jnp.int32)
                   if self._uses_offsets else None)
        cache = self.model.init_cache(b_rows, self.cfg.max_seq)

        out = [tokens]
        step_lat: List[float] = []
        with planned_scope(self.mesh, self.strategy, self.tuning):
            with obs.span("serve.prefill", batch=b_rows, seq=sp):
                logits, cache = self._call_prefill(cache, tokens, offsets)
            if self.cfg.max_new_tokens > 0:
                cur = _sample(logits, self.cfg, key)
                jax.block_until_ready(cur)
                ttft = time.perf_counter() - t_start
                out.append(cur[:, None])
                for t in range(sp, sp + self.cfg.max_new_tokens - 1):
                    key, sub = jax.random.split(key)
                    t0 = time.perf_counter()
                    with obs.span("serve.decode_step", batch=b_rows, pos=t):
                        logits, cache = self._call_step(
                            cache, cur[:, None], jnp.int32(t), offsets)
                        cur = _sample(logits, self.cfg, sub)
                        jax.block_until_ready(cur)
                    step_lat.append(time.perf_counter() - t0)
                    out.append(cur[:, None])
            else:
                jax.block_until_ready(logits)
                ttft = time.perf_counter() - t_start
        full = np.asarray(jnp.concatenate(out, axis=1))
        wall = time.perf_counter() - t_start

        sequences, new_tokens = [], []
        for i in range(n):
            row = full[i]
            seq = row[sp - int(lens[i]):].tolist()   # strip left padding
            sequences.append(seq)
            new_tokens.append(seq[int(lens[i]):])
        if obs.enabled():
            obs.counter("serve.requests").inc(
                n, bucket=bucket.label if bucket else "cold")
            obs.counter("serve.tokens").inc(sum(len(t) for t in new_tokens))
            obs.histogram("serve.ttft_us").observe(ttft * 1e6)
            h = obs.histogram("serve.decode_token_us")
            for dt in step_lat:
                h.observe(dt * 1e6)
        return ServeResult(sequences, new_tokens,
                           bucket.label if bucket else None,
                           ttft, np.asarray(step_lat), wall, probe)

    # -- plan-cache accounting -----------------------------------------------

    def _probe_bucket(self, bucket: Optional[Bucket]) -> Dict[str, int]:
        """Re-``get`` the bucket's warm plan keys: all hits after warmup
        (that IS the 100%-hit-rate pin); an evicted entry is re-pinned from
        the warmup snapshot and counted."""
        if bucket is None or bucket not in self._bucket_plans:
            return {"probed": 0, "missing": 0}
        snapshot = self._bucket_plans[bucket]
        missing = [k for k in snapshot if plan_cache.get(k) is None]
        for k in missing:
            if snapshot[k] is not None:
                plan_cache.put(k, snapshot[k])
        if missing and obs.enabled():
            obs.counter("serve.plan_repin").inc(len(missing))
        out = {"probed": len(snapshot), "missing": len(missing)}
        if self.tuning is not None and hasattr(self.tuning, "lookup_key"):
            tune_keys = self._bucket_tune_keys.get(bucket, ())
            tune_missing = [k for k in tune_keys
                            if self.tuning.lookup_key(k) is None]
            out["tune_probed"] = len(tune_keys)
            out["tune_missing"] = len(tune_missing)
        return out

    def cache_report(self) -> Dict:
        """Plan-cache accounting split at the warmup boundary: the serve
        window's hit rate is 1.0 when every post-warmup lookup (request
        probes + any re-traces) hit -- the acceptance pin for bucketed
        serving."""
        info = plan_cache.info()
        rep: Dict = {"info": info}
        if self._warm_cache_info is not None:
            hits = info["hits"] - self._warm_cache_info["hits"]
            misses = info["misses"] - self._warm_cache_info["misses"]
            total = hits + misses
            rep["serve_window"] = {
                "hits": hits, "misses": misses,
                "hit_rate": (hits / total) if total else None,
            }
        if self.tuning is not None and hasattr(self.tuning, "stats"):
            stats = dict(self.tuning.stats)
            tun: Dict = {
                "entries": len(self.tuning.keys())
                if hasattr(self.tuning, "keys") else None,
                "stats": stats,
            }
            if self._warm_tune_stats is not None:
                hits = stats["hits"] - self._warm_tune_stats["hits"]
                misses = stats["misses"] - self._warm_tune_stats["misses"]
                total = hits + misses
                tun["serve_window"] = {
                    "hits": hits, "misses": misses,
                    "hit_rate": (hits / total) if total else None,
                }
            rep["tuning"] = tun
        return rep

    # -- internals -----------------------------------------------------------

    def _call_prefill(self, cache, tokens, offsets):
        if offsets is not None:
            return self._prefill(self.params, cache, tokens, offsets)
        return self._prefill(self.params, cache, tokens)

    def _call_step(self, cache, cur, pos, offsets):
        if offsets is not None:
            return self._step(self.params, cache, cur, pos, offsets)
        return self._step(self.params, cache, cur, pos)

def warmup(model, params, cfg: ServeConfig, *, mesh=None,
           buckets: Sequence = DEFAULT_BUCKETS,
           strategy: Optional[str] = None, tuning=None) -> Server:
    """Build a ``Server`` and AOT-warm its bucket grid in one call:
    ``server = warmup(model, params, cfg, mesh=mesh, buckets=[(8, 32)])``.
    Returns the warmed server (its ``warmup_report`` attribute holds the
    per-bucket accounting)."""
    server = Server(model, params, cfg, mesh=mesh, strategy=strategy,
                    tuning=tuning, buckets=buckets)
    server.warmup_report = server.warmup()
    return server
