"""repro.serve -- production serving harness on the plan engine.

``Server`` holds persistent compiled prefill/decode functions, AOT-warms a
declared (batch, seq) bucket grid (filling the plan cache with each
bucket's ``SchedulePlan``s), and routes incoming request batches to the
nearest warm bucket via left-padding + position offsets.  See
``repro.runtime.serve`` for the underlying decode loop and
``benchmarks/serve_sweep.py`` for the config-matrix latency sweep.
"""
from .buckets import Bucket, as_bucket, bucket_grid, route
from .server import DEFAULT_BUCKETS, Server, ServeResult, warmup

__all__ = [
    "Bucket", "as_bucket", "bucket_grid", "route",
    "Server", "ServeResult", "warmup", "DEFAULT_BUCKETS",
]
