"""Roofline terms from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip, per link)

``compiled.cost_analysis()`` provides per-device FLOPs / bytes accessed
(XLA compiles the per-device SPMD module).  Collective bytes are *not* in
cost_analysis: ``collective_bytes`` parses the optimized per-device HLO and
sums operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute ops (scan bodies are counted once per trip via the
while-loop trip count when derivable; see _loop_multipliers).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.cost import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"((?:\([^)]*\)|[\w\[\],{}\/ ]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved by each collective kind (output-shape accounting, the
    standard convention for AG/AR volume), summed over the module.
    ``-done`` ops are skipped so async pairs count once."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if m.group(0).rstrip("(").endswith("-done("):
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes (sum kinds)
    coll_by_kind: Dict[str, int]
    model_flops: Optional[float] = None   # 6ND-style useful flops (global)
    chips: int = 1
    xla_flops: float = 0.0                # raw cost_analysis (scan-undercounted)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap bound: the dominant term is the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / self.chips / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MODEL_FLOPS-based MFU bound implied by the three terms."""
        if not self.model_flops:
            return None
        ideal = self.model_flops / self.chips / PEAK_FLOPS_BF16
        return ideal / max(self.step_s, 1e-30)

    def summary(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s_bound": self.step_s,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, chips: int, model_flops: Optional[float] = None) -> Roofline:
    """Build roofline terms from the compiled per-device SPMD module.

    Primary accounting comes from the call-graph HLO analyzer
    (repro.roofline.hlo_stats) because XLA's cost_analysis counts while
    (scan) bodies once; cost_analysis is kept in the record as a cross-check
    lower bound."""
    from repro.roofline.hlo_stats import analyze

    text = compiled.as_text()
    stats = analyze(text)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0] if xla_cost else {}
    return Roofline(
        flops=float(stats.flops),
        hbm_bytes=float(stats.bytes),
        coll_bytes=float(stats.coll_bytes),
        coll_by_kind={k: int(v) for k, v in stats.coll.items()},
        model_flops=model_flops,
        chips=chips,
        xla_flops=float(xla_cost.get("flops", 0.0)) if hasattr(xla_cost, "get") else 0.0,
    )


def train_model_flops(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def infer_model_flops(n_active_params: float, tokens: float) -> float:
    return 2.0 * n_active_params * tokens
