"""repro.roofline -- static HLO analysis + roofline cost terms.

  analysis  -- Roofline terms (compute/memory/collective seconds) from the
               compiled dry-run artifact
  hlo_stats -- call-graph walk over optimized HLO text: FLOPs, HBM bytes,
               collective bytes with while-loop trip multipliers
"""
from repro import jax_compat as _jax_compat

_jax_compat.install()

from . import analysis, hlo_stats  # noqa: E402
from .analysis import Roofline  # noqa: E402
from .hlo_stats import Cost, analyze, analyze_by_shape  # noqa: E402

__all__ = ["analysis", "hlo_stats", "Roofline", "Cost", "analyze",
           "analyze_by_shape"]
