"""Static analyzer for optimized HLO text: FLOPs, HBM bytes, collective
bytes -- with while-loop (scan) bodies multiplied by their known trip
counts.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a while
body ONCE, so every scanned-layer model under-counts by the layer count
(verified: ratio = 1/L).  The optimized HLO, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on while ops, so an exact
accounting is a call-graph walk:

    cost(comp) = sum(direct op costs) + sum over calls:
                   while : trip * (cost(body) + cost(cond))
                   call/conditional : cost(callee)
                   fusion: operands+output bytes only (internals are fused)

Direct op costs:
    dot          : 2 * prod(out dims) * prod(contracting dims) flops,
                   operands+output bytes
    fusion/elemwise: ~1 flop per output element; operands+output bytes
    dynamic-(update-)slice: 2x slice size (in-place semantics)
    collectives  : operand bytes by kind (start/done pairs counted once)
    tuple/gte/bitcast/parameter/constant: free
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*(.+?)\s*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")

_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "reshape",
}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_ATOM.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in _COLLECTIVES:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(
            self.flops * m, self.bytes * m,
            {k: v * m for k, v in self.coll.items()},
        )

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Op:
    name: str
    out_shape: str
    kind: str
    rest: str  # text after the opening paren


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symbols: Dict[str, str]  # op/param name -> output shape string


def _parse(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = re.sub(r", metadata=\{[^}]*\}", "", raw)
        m = _COMP_HDR.match(line)
        if m:
            cur = _Computation(m.group(2), [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, shape, kind, rest = om.groups()
        cur.symbols[name] = shape
        cur.ops.append(_Op(name, shape, kind, rest))
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    _, out_b = _shape_elems_bytes(op.out_shape)
    out_e, _ = _shape_elems_bytes(op.out_shape)
    # operands print either bare (dot(%x, %y)) or typed
    # (dot(f32[..] %x, f32[..] %y)) depending on the XLA dialect
    lhs_m = re.search(r"%([\w.\-]+)", op.rest) or re.match(r"([\w.\-]+)", op.rest)
    contract = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if lhs_m and cm:
        lhs_shape = comp.symbols.get(lhs_m.group(1), "")
        am = _SHAPE_ATOM.search(lhs_shape)
        if am:
            dims = [int(d) for d in am.group(2).split(",") if d]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    return 2.0 * out_e * contract


def _operand_bytes(op: _Op, comp: _Computation, skip=frozenset()) -> float:
    total = 0.0
    for nm in re.findall(r"%([\w.\-]+)", op.rest.split("), ")[0]):
        if nm in skip:
            continue
        shape = comp.symbols.get(nm)
        if shape:
            _, b = _shape_elems_bytes(shape)
            total += b
    return total


# v5e VMEM budget for loop-invariant residency: invariant carries smaller
# than this stay on-chip across iterations and are fetched once, not per
# trip (weight-stationary execution); larger invariants stream per trip.
VMEM_RESIDENT_BYTES = 64 * 1024 * 1024


def _fusion_dus_bytes(inner: _Computation) -> Optional[float]:
    """If the fused computation is rooted in dynamic-update-slice(s) (scan
    ys-stacking / in-place cache writes), return 2x the update-slab bytes;
    else None.  The update operand is the DUS's second argument."""
    if not inner or not inner.ops:
        return None
    roots = [op for op in inner.ops if op.kind == "dynamic-update-slice"]
    if not roots or inner.ops[-1].kind not in ("dynamic-update-slice", "tuple"):
        return None
    if inner.ops[-1].kind == "tuple":
        root_names = set(re.findall(r"%([\w.\-]+)", inner.ops[-1].rest))
        if not all(r.name in root_names for r in roots):
            return None
        if len(root_names) != len(roots):
            return None  # mixed roots: fall back to full accounting
    total = 0.0
    for r in roots:
        args = re.findall(r"%([\w.\-]+)", r.rest)
        if len(args) < 2:
            return None
        _, ub = _shape_elems_bytes(inner.symbols.get(args[1], ""))
        if ub == 0:
            return None
        total += 2.0 * ub
    return total


def _invariant_gtes(comp: _Computation) -> Dict[str, int]:
    """get-tuple-element ops of the loop carry that pass through the body
    ROOT tuple unchanged -> {op name: byte size}."""
    if not comp.ops:
        return {}
    root = comp.ops[-1]
    if root.kind != "tuple":
        return {}
    root_elems = re.findall(r"%([\w.\-]+)", root.rest)
    param_names = {o.name for o in comp.ops if o.kind == "parameter"}
    out: Dict[str, int] = {}
    for op in comp.ops:
        if op.kind != "get-tuple-element":
            continue
        src = re.match(r"%?([\w.\-]+)", op.rest)
        idxm = re.search(r"index=(\d+)", op.rest)
        if not src or not idxm or src.group(1) not in param_names:
            continue
        idx = int(idxm.group(1))
        if idx < len(root_elems) and root_elems[idx] == op.name:
            _, b = _shape_elems_bytes(op.out_shape)
            out[op.name] = b
    return out


def analyze(text: str, invariant_aware: bool = True) -> Cost:
    """invariant_aware: loop-carried operands that pass through a while
    body unchanged and fit VMEM_RESIDENT_BYTES are fetched once per loop,
    not once per trip (TPU weight-stationary residency)."""
    comps, entry = _parse(text)
    if entry is None:
        return Cost()
    memo: Dict = {}

    def cost_of(name: str, skip=frozenset()) -> Cost:
        key = (name, skip)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        total = Cost()
        if comp is None:
            memo[key] = total
            return total
        memo[key] = total  # guards (benign) cycles
        for op in comp.ops:
            out_e, out_b = _shape_elems_bytes(op.out_shape)
            kind = op.kind
            base = kind.replace("-start", "").replace("-done", "")
            if kind in _FREE_OPS:
                continue
            if base in _COLLECTIVES:
                if kind.endswith("-done"):
                    continue
                total.coll[base] += out_b
                total.bytes += out_b + _operand_bytes(op, comp, skip)
                continue
            if kind == "while":
                trip = 1.0
                tm = _TRIP.search(op.rest)
                if tm:
                    trip = float(tm.group(1))
                called = re.findall(r"(?:body|condition)=%?([\w.\-]+)", op.rest)
                resident: Dict[str, int] = {}
                if invariant_aware:
                    for c in called:
                        sub = comps.get(c)
                        if sub is None:
                            continue
                        for nm, b in _invariant_gtes(sub).items():
                            if b <= VMEM_RESIDENT_BYTES:
                                resident[nm] = b
                for c in called:
                    total += cost_of(c, frozenset(resident)).scaled(trip)
                # one HBM fetch for each VMEM-resident invariant
                total.bytes += float(sum(resident.values()))
                continue
            if kind in ("call", "custom-call", "conditional", "async-start"):
                for grp in _CALLED.findall(op.rest):
                    for c in re.split(r",\s*%?", grp):
                        if c and kind != "custom-call":
                            total += cost_of(c)
                if kind == "custom-call":
                    total.bytes += out_b + _operand_bytes(op, comp, skip)
                continue
            if kind == "fusion":
                # internals fused: operands + output traffic, ~1 flop/elem,
                # but count any dots living inside the fused computation
                fm = re.search(r"calls=%?([\w.\-]+)", op.rest)
                inner = comps.get(fm.group(1)) if fm else None
                dus_b = _fusion_dus_bytes(inner) if inner else None
                if dus_b is not None:
                    # in-place update fusion (scan ys-stacking, cache
                    # writes): traffic is 2x the updated slab, not the
                    # whole buffer
                    total.bytes += dus_b
                    total.flops += out_e if out_e < dus_b else dus_b
                else:
                    total.bytes += out_b + _operand_bytes(op, comp, skip)
                    total.flops += out_e
                if inner:
                    for iop in inner.ops:
                        if iop.kind == "dot":
                            total.flops += _dot_flops(iop, inner)
                continue
            if kind == "dot":
                total.flops += _dot_flops(op, comp)
                total.bytes += out_b + _operand_bytes(op, comp, skip)
                continue
            if kind in ("dynamic-update-slice",):
                # in-place: read+write of the update slab
                upd = op.rest.split(",")
                ub = 0.0
                if len(upd) >= 2:
                    nm = re.search(r"%([\w.\-]+)", upd[1])
                    if nm:
                        _, ub = _shape_elems_bytes(comp.symbols.get(nm.group(1), ""))
                total.bytes += 2 * (ub or out_b)
                continue
            if kind in ("dynamic-slice", "gather", "scatter", "copy",
                        "slice", "concatenate", "pad", "transpose",
                        "broadcast", "reduce", "reduce-window", "sort",
                        "convert", "select-and-scatter"):
                total.bytes += out_b + (
                    _operand_bytes(op, comp, skip)
                    if kind in ("reduce", "concatenate", "sort")
                    else out_b
                )
                total.flops += out_e
                continue
            # generic elementwise
            total.bytes += out_b + _operand_bytes(op, comp, skip)
            total.flops += out_e
        memo[key] = total
        return total

    # fusion-called computations are reached only via their call sites; the
    # recursion above handles that, starting from ENTRY.
    return cost_of(entry)


def analyze_by_shape(text: str, top: int = 20, invariant_aware: bool = True):
    """Profile view: (op kind, output shape) -> total bytes with loop
    multipliers -- the dry-run's substitute for a wall-clock profile.
    Returns a sorted list of (key, bytes)."""
    comps, entry = _parse(text)
    if entry is None:
        return []
    acc: Dict[str, float] = {}

    def add(key: str, b: float):
        acc[key] = acc.get(key, 0.0) + b

    def walk(name: str, mult: float, skip=frozenset()):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            out_e, out_b = _shape_elems_bytes(op.out_shape)
            kind = op.kind
            base = kind.replace("-start", "").replace("-done", "")
            if kind in _FREE_OPS or kind.endswith("-done"):
                continue
            if kind == "while":
                trip = 1.0
                tm = _TRIP.search(op.rest)
                if tm:
                    trip = float(tm.group(1))
                called = re.findall(r"(?:body|condition)=%?([\w.\-]+)", op.rest)
                res: Dict[str, int] = {}
                if invariant_aware:
                    for c in called:
                        sub = comps.get(c)
                        if sub:
                            for nm, b in _invariant_gtes(sub).items():
                                if b <= VMEM_RESIDENT_BYTES:
                                    res[nm] = b
                for c in called:
                    walk(c, mult * trip, frozenset(res))
                add("invariant-residency", sum(res.values()) * mult)
                continue
            if kind in ("call", "conditional"):
                for grp in _CALLED.findall(op.rest):
                    for c in re.split(r",\s*%?", grp):
                        if c:
                            walk(c, mult)
                continue
            shape_key = op.out_shape.split("{")[0]
            if base in _COLLECTIVES:
                add(f"COLL:{base} {shape_key}", out_b * mult)
                continue
            if kind == "dynamic-update-slice":
                add(f"{kind} {shape_key}", 2 * out_b * mult)
                continue
            b = out_b + _operand_bytes(op, comp, skip)
            add(f"{kind} {shape_key}", b * mult)

    walk(entry, 1.0)
    return sorted(acc.items(), key=lambda kv: -kv[1])[:top]
