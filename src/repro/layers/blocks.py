"""Residual blocks assembling the layer zoo, + per-block param init.

Block kinds:
  attn_mlp   -- pre-norm attention + dense SwiGLU (llama family, chameleon)
  attn_moe   -- pre-norm attention + MoE (qwen3-moe, deepseek-moe)
  mamba      -- pre-norm Mamba-2 only (zamba2 backbone)
  mlstm/slstm-- xLSTM blocks (no FFN at 350m scale)
  enc_attn_mlp / dec block variants live in models/encdec.py

Every block returns (x, aux, new_cache); aux carries the MoE load-balance
loss.  Activation sharding constraints pin (batch, seq, d_model) layouts at
block boundaries so GSPMD propagates TP shardings inward.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.runtime.sharding import constrain
from .attention import (gqa_attention, gqa_params, mla_attention, mla_params)
from .mamba2 import mamba2, mamba2_params
from .mlp import mlp, mlp_params
from .moe import moe, moe_params
from .norms import rms_norm, rms_norm_params
from .xlstm import mlstm, mlstm_params, slstm, slstm_params

Params = Dict


def block_params(key, cfg: ModelConfig, kind: str, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    if kind == "attn_mlp":
        attn = mla_params if cfg.attn_type == "mla" else gqa_params
        return {
            "attn_norm": rms_norm_params(d),
            "attn": attn(k1, cfg, dtype),
            "mlp_norm": rms_norm_params(d),
            "mlp": mlp_params(k2, d, cfg.d_ff, dtype),
        }
    if kind == "attn_moe":
        attn = mla_params if cfg.attn_type == "mla" else gqa_params
        return {
            "attn_norm": rms_norm_params(d),
            "attn": attn(k1, cfg, dtype),
            "mlp_norm": rms_norm_params(d),
            "moe": moe_params(k2, cfg, dtype),
        }
    if kind == "mamba":
        return {"norm": rms_norm_params(d), "mamba": mamba2_params(k1, cfg, dtype)}
    if kind == "mlstm":
        return {"norm": rms_norm_params(d), "mlstm": mlstm_params(k1, cfg, dtype)}
    if kind == "slstm":
        return {"norm": rms_norm_params(d), "slstm": slstm_params(k1, cfg, dtype)}
    raise ValueError(kind)


def block_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, kind: str,
    positions: jax.Array,
    cache: Optional[Dict] = None,
    pos: Optional[jax.Array] = None,
    offsets: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """``offsets`` (B,) carries per-row left-padding amounts down to the
    attention layers (logical-position masking for padded serving batches);
    the recurrent kinds have no position concept and ignore it."""
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "batch", None, None)
    if kind in ("attn_mlp", "attn_moe"):
        attn_fn = mla_attention if cfg.attn_type == "mla" else gqa_attention
        h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
        a, new_cache = attn_fn(p["attn"], h, cfg, positions, cache, pos,
                               offsets=offsets)
        x = x + constrain(a, "batch", None, None)
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if kind == "attn_mlp":
            m = mlp(p["mlp"], h)
        else:
            m, aux = moe(p["moe"], h, cfg)
        x = x + constrain(m, "batch", None, None)
        return x, aux, new_cache
    if kind == "mamba":
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        m, new_cache = mamba2(p["mamba"], h, cfg, cache, pos)
        return x + m, aux, new_cache
    if kind == "mlstm":
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        m, new_cache = mlstm(p["mlstm"], h, cfg, cache, pos)
        return x + m, aux, new_cache
    if kind == "slstm":
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        m, new_cache = slstm(p["slstm"], h, cfg, cache, pos)
        return x + m, aux, new_cache
    raise ValueError(kind)
