"""Attention layers: GQA (optionally sliding-window) and MLA.

Two execution paths:
  * ``xla``   -- chunked masked einsum (scan over query chunks keeps the
                 score matrix O(chunk x S) instead of O(S^2)); this is the
                 path the multi-pod dry-run lowers, and its matmuls carry the
                 sharding annotations that GSPMD turns into collectives.
  * ``flash`` -- the Pallas kernel (repro.kernels.flash_attention) for real
                 TPU runs; numerically validated against the same oracle.

Decode paths maintain a KV cache: full cache for GQA, rolling window cache
for SWA (h2o-danube at 500k), and the *compressed latent* cache for MLA with
the absorbed-matmul decode (w_uk/w_uv folded into the query/output products
-- a schedule re-association in the spirit of the paper: same instruction
set X, different equivariant map).

The qkv/output projections route through ``layers.linear``: inside a
``repro.plan.planned_matmuls(mesh)`` scope they dispatch through the plan
engine (mesh-aware schedule, plan cache) instead of the purely local
multiply.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from .linear import linear, linear_params
from .norms import rms_norm, rms_norm_params
from .rope import apply_rope

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# masked chunked attention core (shared by GQA and MLA expanded paths)
# ---------------------------------------------------------------------------


def _mask(qpos: jax.Array, kpos: jax.Array, window: int,
          causal: bool = True) -> jax.Array:
    """(Lq, Skv) -- or, with batched positions, (B, Lq, Skv) -- boolean
    mask: causal + optional sliding window.  Negative key positions
    (unwritten rolling-cache slots and left-padding slots, whose logical
    position is slot - offset < 0) are always invalid.  ``qpos``/``kpos``
    may be (L,)/(S,) or per-row (B, L)/(B, S); the two layouts broadcast."""
    if causal:
        m = kpos[..., None, :] <= qpos[..., :, None]
    else:
        m = jnp.ones(jnp.broadcast_shapes(
            qpos[..., :, None].shape, kpos[..., None, :].shape), bool)
    m = jnp.logical_and(m, (kpos >= 0)[..., None, :])
    if window > 0:
        m = jnp.logical_and(m, kpos[..., None, :] > qpos[..., :, None] - window)
    return m


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, qpos, kpos,
          window: int, scale: float, causal: bool = True,
          probs_dtype=jnp.float32) -> jax.Array:
    """q: (B, L, Hkv, G, Dk); k: (B, S, Hkv, Dk); v: (B, S, Hkv, Dv).

    Softmax statistics stay fp32; ``probs_dtype=bf16`` stores the
    probability matrix (the dominant S^2 traffic) at half width before the
    PV product -- the Sec.-Perf memory-term optimization.  QK/PV einsums
    run on native (bf16) operands with fp32 accumulation -- the MXU-native
    mode -- instead of materializing fp32 copies of K/V-cache-sized
    tensors (Sec. Perf, hillclimb C it2)."""
    s = jnp.einsum(
        "blhgd,bshd->blhgs", q, k, preferred_element_type=jnp.float32
    ) * scale
    m = _mask(qpos, kpos, window, causal)              # (L, S) or (B, L, S)
    if m.ndim == 2:
        m = m[None]
    s = jnp.where(m[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(probs_dtype)
    o = jnp.einsum(
        "blhgs,bshd->blhgd", p, v.astype(probs_dtype),
        preferred_element_type=jnp.float32,
    )
    return o.astype(v.dtype)


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qpos: jax.Array, kpos: jax.Array,
    *, window: int = 0, chunk: int = 1024, scale: Optional[float] = None,
    causal: bool = True, probs_dtype=jnp.float32,
) -> jax.Array:
    """q: (B, Sq, H, Dk) grouped against k/v: (B, Skv, Hkv, D*).
    Scans over query chunks so peak memory is O(B*chunk*H*Skv)."""
    b, sq, h, dk = q.shape
    _, skv, hkv, dv = v.shape
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    qg = q.reshape(b, sq, hkv, g, dk)
    if sq <= chunk:
        o = _sdpa(qg, k, v, qpos, kpos, window, scale, causal, probs_dtype)
        return o.reshape(b, sq, h, dv)
    assert sq % chunk == 0, (sq, chunk)
    nc = sq // chunk
    qc = qg.reshape(b, nc, chunk, hkv, g, dk).transpose(1, 0, 2, 3, 4, 5)
    if qpos.ndim == 2:  # per-row positions (B, Sq): chunk alongside q
        pc = qpos.reshape(b, nc, chunk).transpose(1, 0, 2)
    else:
        pc = qpos.reshape(nc, chunk)

    def body(_, qp):
        qi, pi = qp
        return None, _sdpa(qi, k, v, pi, kpos, window, scale, causal, probs_dtype)

    _, oc = jax.lax.scan(body, None, (qc, pc))
    o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dv)
    return o


# ---------------------------------------------------------------------------
# GQA (covers MHA, MQA, SWA)
# ---------------------------------------------------------------------------


def gqa_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_params(ks[0], d, h * hd, dtype),
        "wk": linear_params(ks[1], d, kv * hd, dtype),
        "wv": linear_params(ks[2], d, kv * hd, dtype),
        "wo": linear_params(ks[3], h * hd, d, dtype),
    }


def gqa_attention(
    p: Params, x: jax.Array, cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,
    causal: bool = True,
    offsets: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    """x: (B, S, d).  Training/prefill when cache is None (or being filled);
    decode when cache is provided with scalar ``pos`` (S == 1).

    ``offsets`` (B,) shifts each row's logical positions for left-padded
    serving batches: cache slot j holds row i's logical position
    j - offsets[i], so padding slots land at negative positions and the
    ``kpos >= 0`` mask removes them -- a row left-padded by ``offsets[i]``
    attends to exactly the keys it would see decoded alone.  ``positions``
    must then be the matching per-row logical query positions (B, S)."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(x, p["wq"]).reshape(b, s, h, hd)
    k = linear(x, p["wk"]).reshape(b, s, kv, hd)
    v = linear(x, p["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    pdt = jnp.bfloat16 if cfg.attn_probs_dtype == "bf16" else jnp.float32
    if cache is None:  # train / prefill without cache materialization
        o = chunked_attention(
            q, k, v, positions, positions,
            window=cfg.window, chunk=cfg.attn_chunk, causal=causal,
            probs_dtype=pdt,
        )
        new_cache = None
    else:
        s_cache = cache["k"].shape[1]
        rolling = cfg.window > 0 and s_cache == cfg.window
        slot = (pos % s_cache) if rolling else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        idx = jnp.arange(s_cache)
        if rolling:
            # slot i holds position pos - ((pos - i) mod W); invalid (< 0)
            # slots are masked by the causal check against qpos = pos.
            kpos = pos - jnp.mod(pos - idx, s_cache)
        else:
            kpos = idx
        if offsets is not None:
            kpos = kpos[None, :] - offsets[:, None]  # per-row logical slots
        o = chunked_attention(
            q, ck, cv, positions, kpos,
            window=cfg.window, chunk=cfg.attn_chunk, probs_dtype=pdt,
        )
    o = linear(o.reshape(b, s, h * hd), p["wo"])
    return o, new_cache


def gqa_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Cache:
    s = min(max_seq, cfg.window) if cfg.window > 0 else max_seq
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, s, kv, hd), dtype),
        "v": jnp.zeros((batch, s, kv, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (minicpm3): latent-compressed KV with absorbed decode
# ---------------------------------------------------------------------------


def mla_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": linear_params(ks[0], d, qr, dtype),
        "q_norm": rms_norm_params(qr),
        "wq_b": linear_params(ks[1], qr, h * (nope + rope), dtype),
        "wkv_a": linear_params(ks[2], d, kvr + rope, dtype),
        "kv_norm": rms_norm_params(kvr),
        "wkv_b": linear_params(ks[3], kvr, h * (nope + vd), dtype),
        "wo": linear_params(ks[4], h * vd, d, dtype),
    }


def _mla_q(p: Params, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = linear(rms_norm(linear(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Params, x, cfg: ModelConfig, positions):
    kvr, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = linear(x, p["wkv_a"])
    c_kv = rms_norm(kv[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., kvr:][:, :, None, :]  # (B, S, 1, rope): shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(
    p: Params, x: jax.Array, cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,
    offsets: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    b, s, d = x.shape
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(nope + rope)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)

    if cache is None:
        # expanded path: materialize per-head K/V from the latent
        kvb = linear(c_kv, p["wkv_b"]).reshape(b, s, h, nope + vd)
        k_nope, v = kvb[..., :nope], kvb[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = chunked_attention(
            q, k, v, positions, positions, chunk=cfg.attn_chunk, scale=scale,
            probs_dtype=jnp.bfloat16 if cfg.attn_probs_dtype == "bf16"
            else jnp.float32,
        )
        new_cache = None
    else:
        # absorbed decode: attend in the kv_lora_rank latent space
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        # wkv_b columns are per-head blocks of (nope + vd) -- must match the
        # expanded path's reshape(b, s, h, nope + vd) exactly
        w_b = p["wkv_b"].reshape(kvr, h, nope + vd)
        w_uk = w_b[:, :, :nope]
        w_uv = w_b[:, :, nope:]
        q_c = jnp.einsum(  # fold w_uk into q
            "bshn,lhn->bshl", q_nope.astype(jnp.float32),
            w_uk.astype(jnp.float32),
        )
        sc = jnp.einsum("bshl,btl->bsht", q_c, cc.astype(jnp.float32))
        sc += jnp.einsum(
            "bshr,btr->bsht", q_rope.astype(jnp.float32),
            cr.astype(jnp.float32),
        )
        sc *= scale
        kpos = jnp.arange(cc.shape[1])
        if offsets is not None:
            # per-row logical slot positions; left-padding slots (< 0)
            # are masked out, matching the GQA kpos >= 0 convention
            kpos_b = kpos[None, :] - offsets[:, None]        # (B, T)
            valid = jnp.logical_and(
                kpos_b[:, None, :] <= positions[:, :, None],  # (B, S, T)
                kpos_b[:, None, :] >= 0)
            sc = jnp.where(valid[:, :, None, :], sc, -1e30)
        else:
            valid = kpos[None, :] <= positions[:, None]      # (S, T)
            sc = jnp.where(valid[None, :, None, :], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1)
        att_c = jnp.einsum("bsht,btl->bshl", pr, cc.astype(jnp.float32))
        o = jnp.einsum("bshl,lhv->bshv", att_c, w_uv.astype(jnp.float32))
        o = o.astype(x.dtype)
    o = linear(o.reshape(b, s, h * vd), p["wo"])
    return o, new_cache


def mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Cache:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
    }
