"""Mamba-2 (SSD) layer: chunked matmul formulation + O(1) decode.

The chunked state-space-dual algorithm is MXU-shaped on purpose: within a
chunk of length L the output is a masked (L x L) matmul, and chunk-to-chunk
state is a rank-L update -- i.e. exactly the paper's blocked schedule story
applied to a recurrence (the chunk length plays the role of the time
superstep T_l).  Scalar-per-head decay (Mamba-2's simplification) keeps the
decay algebra in the exponent domain.

Shapes: d_in = expand * d_model; H = d_in / headdim heads; state N.
B_t and C_t are shared across heads (n_groups = 1).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .linear import linear, linear_params
from .norms import rms_norm, rms_norm_params

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]


def mamba2_params(key, cfg, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = din // cfg.ssm_headdim
    kconv = cfg.conv_kernel
    ks = jax.random.split(key, 6)
    conv_ch = din + 2 * n
    return {
        # in_proj -> [z, x, B, C, dt]
        "in_proj": linear_params(ks[0], d, 2 * din + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (kconv, conv_ch), jnp.float32)
                   * (1.0 / kconv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rms_norm_params(din),
        "out_proj": linear_params(ks[2], din, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K is 4: unrolled taps stay fused
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunk_scan(xh, dt, Bm, Cm, A, chunk: int, gate_dtype=None):
    """Chunked SSD. xh: (B, S, H, P); dt: (B, S, H); Bm, Cm: (B, S, N);
    A: (H,) negative.  Returns y: (B, S, H, P) and final state (B, H, P, N)."""
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:  # trailing zero-pad is causal-safe; outputs sliced back below
        z = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xh, dt, Bm, Cm = z(xh), z(dt), z(Bm), z(Cm)
        s_orig, s = s, s + pad
    else:
        s_orig = s
    nc = s // chunk
    L = chunk

    # reshape to chunks, scan axis first
    def toc(t):
        return t.reshape(b, nc, L, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = toc(xh), toc(dt), toc(Bm), toc(Cm)
    la = dtc.astype(jnp.float32) * A  # (nc, B, L, H): log decay per step

    def body(hstate, args):
        xk, dtk, Bk, Ck, lak = args
        # cumulative log decay within chunk (inclusive)
        cum = jnp.cumsum(lak, axis=1)                       # (B, L, H)
        # intra-chunk: y_i = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
        scores = jnp.einsum("bin,bjn->bij", Ck.astype(jnp.float32),
                            Bk.astype(jnp.float32))          # (B, L, L)
        decay = cum[:, :, None, :] - cum[:, None, :, :]      # (B, L, L, H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        w = scores[..., None] * gate * dtk[:, None, :, :]    # (B, L, L, H)
        if gate_dtype is not None:
            w = w.astype(gate_dtype)
        y = jnp.einsum("bijh,bjhp->bihp", w, xk.astype(w.dtype),
                       preferred_element_type=jnp.float32)
        # inter-chunk: y_i += exp(cum_i) * C_i . h_prev
        y = y + jnp.einsum(
            "bin,bhpn,bih->bihp", Ck.astype(jnp.float32), hstate,
            jnp.exp(cum),
        )
        # state update: h = exp(cum_L) h_prev + sum_j exp(cum_L - cum_j) dt_j B_j x_j
        tot = cum[:, -1:, :]                                 # (B, 1, H)
        carry_decay = jnp.exp(tot - cum)                     # (B, L, H)
        hnew = jnp.einsum(
            "bjh,bjn,bjhp->bhpn",
            carry_decay * dtk, Bk.astype(jnp.float32), xk.astype(jnp.float32),
        )
        hstate = hstate * jnp.exp(tot[:, 0, :])[:, :, None, None] + hnew
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hfin, yc = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc, la))
    y = yc.swapaxes(0, 1).reshape(b, s, h, p)[:, :s_orig]
    return y, hfin


def mamba2(
    p: Params, x: jax.Array, cfg,
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    """x: (B, S, d_model).  cache (decode): conv state + ssm state."""
    b, s, d = x.shape
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = din // cfg.ssm_headdim
    ph = cfg.ssm_headdim

    proj = linear(x, p["in_proj"])
    z, xr, Bm, Cm, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)

    if cache is None:
        conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_cache = None
    else:
        # decode: shift conv state, apply taps at the newest position
        k = cfg.conv_kernel
        cs = jnp.concatenate([cache["conv"][:, 1:], conv_in], axis=1)  # (B,K,C)
        conv = (
            jnp.einsum("bkc,kc->bc", cs.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32)
        )[:, None, :].astype(x.dtype)
        new_cache = {"conv": cs}

    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xr, Bm, Cm = jnp.split(conv, [din, din + n], axis=-1)
    xh = xr.reshape(b, s, h, ph)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    A = -jnp.exp(p["A_log"])                                        # (H,)

    if cache is None:
        chunk = min(cfg.ssm_chunk, s)
        gdt = jnp.bfloat16 if getattr(cfg, "gate_dtype", "fp32") == "bf16" else None
        y, _ = _ssd_chunk_scan(xh, dt, Bm, Cm, A, chunk, gate_dtype=gdt)
    else:
        # O(1) recurrent step: hstate (B, H, P, N)
        hprev = cache["ssm"]
        a = jnp.exp(dt[:, 0, :] * A)                                # (B,H)
        upd = jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0, :], Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        hstate = hprev * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), hstate)
        y = y[:, None]                                              # (B,1,H,P)
        new_cache["ssm"] = hstate

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)      # gated
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return linear(y, p["out_proj"]), new_cache


def mamba2_cache(cfg, batch: int, dtype=jnp.bfloat16) -> Cache:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = din // cfg.ssm_headdim
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel, din + 2 * n), dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_headdim, n), jnp.float32),
    }
