"""Ring-TP MLP block: the paper-derived collective matmuls at layer level.

The GSPMD path (repro.layers.mlp + sharding rules) lets XLA choose the
collective schedule.  This block *prescribes* it: Megatron-SP layout with
the all-gather and reduce-scatter decomposed into one-hop ppermute chains
overlapped with per-chunk matmuls (repro.dist.ring) -- the 1-D solutions
of the paper's torus equations, and the beyond-paper overlap feature
(paper Sec. 5 future-work item (f)).

Layout contract (inside shard_map over the full mesh):
  x_in  : (B_loc, S/tp, d)  -- sequence-sharded activations (SP)
  out   : (B_loc, S/tp, d)  -- same
  w_gate/w_up : (d, f/tp)   -- column-parallel shards
  w_down      : (f/tp, d)   -- row-parallel shard

Numerics identical to the GSPMD block (tested in
tests/test_ring_blocks.py); the difference is the collective schedule.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.dist.ring import ring_ag_matmul, ring_rs_matmul

Params = Dict[str, jax.Array]


def ring_mlp(p_local: Params, x: jax.Array, tp_axis: str = "model") -> jax.Array:
    """Inside shard_map.  x: (B, S_loc, d) sequence-sharded over tp_axis;
    p_local: per-device shards of w_gate/w_up (d, f_loc), w_down (f_loc, d).
    """
    # ring all-gather matmuls: (B, S_loc, d) -> (B, S, f_loc), overlapped
    g = ring_ag_matmul(x, p_local["w_gate"], tp_axis)
    u = ring_ag_matmul(x, p_local["w_up"], tp_axis)
    h = jax.nn.silu(g) * u
    # ring reduce-scatter matmul: (B, S, f_loc) -> (B, S_loc, d), reduced
    return ring_rs_matmul(h, p_local["w_down"], tp_axis)


def gspmd_mlp_reference(p: Params, x: jax.Array) -> jax.Array:
    """The plain data-flow the GSPMD path computes (global view)."""
    g = jax.nn.silu(
        jnp.matmul(x, p["w_gate"], preferred_element_type=jnp.float32)
    ).astype(x.dtype)
    u = jnp.matmul(x, p["w_up"], preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.matmul(
        (g.astype(jnp.float32) * u.astype(jnp.float32)).astype(x.dtype),
        p["w_down"], preferred_element_type=jnp.float32,
    ).astype(x.dtype)
