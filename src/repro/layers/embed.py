"""Token embedding + LM head, vocab padded to a TP-friendly multiple.

Vocab sizes in the wild (73448, 256206, ...) rarely divide the model axis;
replicating the logits tensor instead costs tens of GiB per device at 32k
sequence (measured: seamless prefill_32k went 63.6 GiB/device).  Standard
production fix (Megatron's make-vocab-size-divisible): pad the embedding
rows to a multiple of 256, shard vocab, and mask the padded logit columns
with -inf so softmax/argmax semantics are exact.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]

VOCAB_ALIGN = 256
_NEG = -1e30


def padded_vocab(vocab: int, align: int = VOCAB_ALIGN) -> int:
    return (vocab + align - 1) // align * align


def embed_params(key, vocab: int, d: int, tie: bool, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    vp = padded_vocab(vocab)
    p = {"embedding": (jax.random.normal(k1, (vp, d), jnp.float32) * 0.02).astype(dtype)}
    if not tie:
        p["lm_head"] = (jax.random.normal(k2, (d, vp), jnp.float32) * 0.02).astype(dtype)
    return p


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: Params, x: jax.Array, vocab: int) -> jax.Array:
    """Returns fp32 logits over the PADDED vocab with padded columns masked
    to -inf (callers keep the padded width; CE/argmax are exact)."""
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    logits = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    vp = logits.shape[-1]
    if vp != vocab:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < vocab, logits, _NEG)
    return logits
