"""Mixture-of-Experts: top-k token-choice routing with capacity (GShard
style dispatch/combine einsums) + optional always-on shared experts
(deepseek-moe).

Dispatch and combine are one-hot einsums so that expert parallelism is pure
sharding: expert weights are sharded over the ``model`` axis, the dispatched
activations (N, E, C, d) get an all-to-all from GSPMD, and every matmul
stays MXU-shaped.  Tokens route in *groups* of ``moe_group_size`` (the
GShard grouping) so the dispatch tensors stay O(tokens * E * C / g) -- with
the per-group capacity C = g*k/E * factor this is O(tokens * k * factor)
per expert slot, independent of sequence length.  Tokens beyond capacity
are dropped (standard dropped-token semantics).  The router runs in fp32
with a Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .mlp import mlp, mlp_params

Params = Dict[str, jax.Array]


def moe_params(key, cfg, dtype=jnp.bfloat16) -> Params:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "w_gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, d), jnp.float32) * ff ** -0.5).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_params(
            ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts, dtype
        )
    return p


def _capacity(group: int, num_experts: int, top_k: int, factor: float) -> int:
    cap = int(group * top_k / num_experts * factor)
    return max(4, (cap + 3) // 4 * 4)


def moe(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g = min(getattr(cfg, "moe_group_size", 256), s)
    assert s % g == 0, (s, g)
    n = b * (s // g)
    cap = _capacity(g, e, k, cfg.capacity_factor)
    xg = x.reshape(n, g, d)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (N,g,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (N,g,k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # per-choice accumulation keeps intermediates at (N, g, E, C)
    dispatch = jnp.zeros((n, g, e, cap), jnp.float32)
    combine = jnp.zeros((n, g, e, cap), jnp.float32)
    counts = jnp.zeros((n, 1, e), jnp.float32)                    # used slots
    for c in range(k):
        oh = jax.nn.one_hot(expert_idx[:, :, c], e, dtype=jnp.float32)
        pos = jnp.cumsum(oh, axis=1) - 1.0 + counts               # (N,g,E)
        keep = (pos < cap) * oh
        slot = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
        sel = jax.nn.one_hot(slot, cap, dtype=jnp.float32) * keep[..., None]
        dispatch = dispatch + sel
        combine = combine + sel * gate_vals[:, :, c, None, None]
        counts = counts + jnp.sum(keep, axis=1, keepdims=True)

    xe = jnp.einsum("ngd,ngec->necd", xg.astype(jnp.float32), dispatch).astype(
        x.dtype
    )                                                             # (N,E,C,d)
    h = jax.nn.silu(
        jnp.einsum("necd,edf->necf", xe, p["w_gate"]).astype(jnp.float32)
    ) * jnp.einsum("necd,edf->necf", xe, p["w_up"]).astype(jnp.float32)
    ye = jnp.einsum("necf,efd->necd", h.astype(x.dtype), p["w_down"])
    y = jnp.einsum("necd,ngec->ngd", ye.astype(jnp.float32), combine)
    y = y.astype(x.dtype).reshape(b, s, d)

    if "shared" in p:
        y = y + mlp(p["shared"], x)

    # Switch load-balance loss: E * mean_e f_e * P_e
    f = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(axis=2), axis=1
    )                                                             # (N,E)
    pmean = jnp.mean(probs, axis=1)                               # (N,E)
    aux = e * jnp.mean(jnp.sum(f * pmean, axis=-1))
    return y, aux
