"""Linear layers routed through the symmetry-scheduled matmul engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.local import local_matmul
from repro.plan.context import planned_mesh, planned_strategy, planned_tuning


def linear_params(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with fp32 accumulation.

    Two paths:
      * default -- the GSPMD baseline: a local multiply (Pallas kernel on
        TPU/GPU, fp32-accumulating jnp elsewhere); sharding of w (and hence
        the collective schedule) comes from the param PartitionSpecs.
      * inside ``repro.plan.planned_matmuls(mesh)`` -- the product dispatches
        through the plan engine: cost-model-ranked strategy, cached
        ``SchedulePlan``, leading (batch, seq) dims folded into the matmul
        rows before planning.  This is how the whole layer stack (mlp,
        attention, moe ride on this function) gets a mesh-aware schedule
        without threading a mesh argument through every call.
    """
    mesh = planned_mesh()
    if mesh is not None and getattr(mesh, "size", 1) > 1:
        from repro.dist.api import symmetric_matmul

        return symmetric_matmul(x, w, mesh=mesh, out_dtype=x.dtype,
                                strategy=planned_strategy(),
                                tuning=planned_tuning())
    return local_matmul(x, w, out_dtype=x.dtype)
