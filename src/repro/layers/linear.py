"""Linear layers routed through the symmetry-scheduled matmul engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.local import local_matmul


def linear_params(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with fp32 accumulation.  The GSPMD baseline path: sharding of w
    (and hence the collective schedule) comes from the param PartitionSpecs;
    ring strategies replace this call inside shard_map blocks (see
    repro.dist.api.symmetric_matmul).  The local multiply routes through
    repro.dist.local (Pallas kernel on TPU/GPU, fp32-accumulating jnp
    elsewhere)."""
    return local_matmul(x, w, out_dtype=x.dtype)
