"""xLSTM layers: chunked-parallel mLSTM and sequential sLSTM.

mLSTM (matrix memory, covariance update) is run in a chunkwise-parallel
matmul form -- the intra-chunk part is a masked (L x L) product, the
inter-chunk part a rank-L state update -- mirroring the SSD schedule (and,
in this repo's framing, the paper's blocked time-superstep schedule).
Gates use bounded sigmoids (f, i in (0,1)); this differs from the xLSTM
paper's exponential input gate + stabilizer track and is recorded in
DESIGN.md: the bounded variant needs no stabilizer state and is exact in
fp32 at our chunk sizes.

sLSTM (scalar memory, new memory mixing) is inherently sequential
(recurrent weights R act on h_{t-1}); it runs as a lax.scan over time with
block-diagonal (per-head) recurrence, exactly as the paper's Sec.-4.3
"no-symmetry-to-exploit" fallback predicts.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .linear import linear, linear_params
from .norms import rms_norm, rms_norm_params

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_params(key, cfg, dtype=jnp.bfloat16) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    return {
        "wq": linear_params(ks[0], d, d, dtype),
        "wk": linear_params(ks[1], d, d, dtype),
        "wv": linear_params(ks[2], d, d, dtype),
        "w_gates": linear_params(ks[3], d, 2 * h, jnp.float32),  # i, f per head
        "gate_bias": jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), 3.0 * jnp.ones((h,), jnp.float32)]
        ),  # forget bias ~ sigmoid(3) = .95
        "norm": rms_norm_params(d),
        "wo": linear_params(ks[4], d, d, dtype),
    }


def _mlstm_chunk_scan(q, k, v, li, lf, chunk: int, gate_dtype=None):
    """q,k,v: (B, S, H, D); li, lf: (B, S, H) log input/forget gates.
    Returns y: (B, S, H, D), final (C, n) state.  gate_dtype=bf16 stores the
    (L, L, H) decay/weight matrices at half width (Sec.-Perf knob)."""
    b, s, h, dh = q.shape
    pad = (-s) % chunk
    if pad:  # causal-safe trailing pad; sliced back at return
        z = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        q, k, v, li, lf = z(q), z(k), z(v), z(li), z(lf)
        s_orig, s = s, s + pad
    else:
        s_orig = s
    nc, L = s // chunk, chunk
    scale = dh ** -0.5

    def toc(t):
        return t.reshape(b, nc, L, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(toc, (q, k, v, li, lf))

    def body(carry, args):
        C, nrm = carry  # C: (B,H,D,D)  nrm: (B,H,D)
        qk, kk, vk, lik, lfk = args
        cum = jnp.cumsum(lfk, axis=1)                    # (B,L,H)
        # intra-chunk attention-like term
        sc = jnp.einsum("bihd,bjhd->bijh", qk.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
        decay = cum[:, :, None, :] - cum[:, None, :, :] + lik[:, None, :, :]
        mask = jnp.tril(jnp.ones((L, L), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        w = sc * gate                                    # (B,L,L,H)
        if gate_dtype is not None:
            w = w.astype(gate_dtype)
        y = jnp.einsum("bijh,bjhd->bihd", w, vk.astype(w.dtype),
                       preferred_element_type=jnp.float32)
        # inter-chunk: y_i += exp(cum_i) q_i . C ; denominator via n
        y = y + jnp.einsum(
            "bihd,bhde,bih->bihe", qk.astype(jnp.float32), C, jnp.exp(cum)
        ) * scale
        qn = jnp.einsum(
            "bihd,bhd,bih->bih", qk.astype(jnp.float32), nrm, jnp.exp(cum)
        ) * scale
        qn = qn + jnp.einsum("bijh,bjhd,bihd->bih", gate,
                             kk.astype(jnp.float32),
                             qk.astype(jnp.float32)) * scale
        denom = jnp.maximum(jnp.abs(qn), 1.0)[..., None]
        y = y / denom
        # state update
        tot = cum[:, -1:, :]
        cd = jnp.exp(tot - cum + lik)                    # (B,L,H)
        C = C * jnp.exp(tot[:, 0])[:, :, None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", cd, kk.astype(jnp.float32),
            vk.astype(jnp.float32),
        )
        nrm = nrm * jnp.exp(tot[:, 0])[:, :, None] + jnp.einsum(
            "bjh,bjhd->bhd", cd, kk.astype(jnp.float32)
        )
        return (C, nrm), y

    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    (Cf, nf), yc = jax.lax.scan(body, (C0, n0), (qc, kc, vc, lic, lfc))
    return yc.swapaxes(0, 1).reshape(b, s, h, dh)[:, :s_orig], (Cf, nf)


def mlstm(
    p: Params, x: jax.Array, cfg,
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    q = linear(x, p["wq"]).reshape(b, s, h, dh)
    k = linear(x, p["wk"]).reshape(b, s, h, dh)
    v = linear(x, p["wv"]).reshape(b, s, h, dh)
    gates = (
        jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_gates"])
        + p["gate_bias"]
    )
    li = jax.nn.log_sigmoid(gates[..., :h])              # (B,S,H)
    lf = jax.nn.log_sigmoid(gates[..., h:])

    if cache is None:
        chunk = min(getattr(cfg, "ssm_chunk", 256), s)
        gdt = jnp.bfloat16 if getattr(cfg, "gate_dtype", "fp32") == "bf16" else None
        y, _ = _mlstm_chunk_scan(q, k, v, li, lf, chunk, gate_dtype=gdt)
        new_cache = None
    else:
        C, nrm = cache["C"], cache["n"]
        f = jnp.exp(lf[:, 0])                            # (B,H)
        i = jnp.exp(li[:, 0])
        C = C * f[:, :, None, None] + jnp.einsum(
            "bhd,bhe,bh->bhde", k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), i,
        )
        nrm = nrm * f[:, :, None] + k[:, 0].astype(jnp.float32) * i[:, :, None]
        scale = dh ** -0.5
        y = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), C) * scale
        qn = jnp.einsum("bhd,bhd->bh", q[:, 0].astype(jnp.float32), nrm) * scale
        y = y / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
        y = y[:, None]
        new_cache = {"C": C, "n": nrm}

    y = y.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return linear(y, p["wo"]), new_cache


def mlstm_cache(cfg, batch: int) -> Cache:
    h = cfg.num_heads
    dh = cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_params(key, cfg, dtype=jnp.bfloat16) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "w_in": linear_params(ks[0], d, 4 * d, jnp.float32),  # z, i, f, o
        "r": (jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32) * dh ** -0.5),
        "bias": jnp.concatenate(
            [jnp.zeros((2 * d,), jnp.float32), 3.0 * jnp.ones((d,), jnp.float32),
             jnp.zeros((d,), jnp.float32)]
        ),
        "norm": rms_norm_params(d),
        "wo": linear_params(ks[2], d, d, dtype),
    }


def _slstm_step(p, cfg, carry, wx_t):
    """carry: (h, c, n) each (B, H, Dh); wx_t: (B, 4d) precomputed W x_t."""
    hprev, cprev, nprev = carry
    b = hprev.shape[0]
    hcfg = cfg.num_heads
    dh = cfg.d_model // hcfg
    rec = jnp.einsum("bhd,ghde->bghe", hprev, p["r"])     # (B,4,H,Dh)
    pre = wx_t.reshape(b, 4, hcfg, dh) + rec + p["bias"].reshape(4, hcfg, dh)
    z = jnp.tanh(pre[:, 0])
    i = jax.nn.sigmoid(pre[:, 1])
    f = jax.nn.sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    c = f * cprev + i * z
    n = f * nprev + i
    hnew = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return (hnew, c, n), hnew


def slstm(
    p: Params, x: jax.Array, cfg,
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    wx = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["w_in"])

    if cache is None:
        carry0 = tuple(jnp.zeros((b, h, dh), jnp.float32) for _ in range(3))
        step = lambda c, w: _slstm_step(p, cfg, c, w)
        _, ys = jax.lax.scan(step, carry0, wx.swapaxes(0, 1))
        y = ys.swapaxes(0, 1).reshape(b, s, d)
        new_cache = None
    else:
        carry = (cache["h"], cache["c"], cache["n"])
        carry, ys = _slstm_step(p, cfg, carry, wx[:, 0])
        y = ys.reshape(b, 1, d)
        new_cache = {"h": carry[0], "c": carry[1], "n": carry[2]}

    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    return linear(y, p["wo"]), new_cache


def slstm_cache(cfg, batch: int) -> Cache:
    h = cfg.num_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"h": z, "c": z, "n": z}
