"""SwiGLU MLP (llama family standard).

All three projections route through ``layers.linear``, so inside a
``repro.plan.planned_matmuls(mesh)`` scope the gate/up/down matmuls each
dispatch through the plan engine (cost-model-ranked strategy, cached
plan, (B, S) folded into the matmul rows); outside it they are the local
GSPMD-baseline multiplies.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .linear import linear, linear_params

Params = Dict[str, jax.Array]


def mlp_params(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": linear_params(ks[0], d, d_ff, dtype),
        "w_up": linear_params(ks[1], d, d_ff, dtype),
        "w_down": linear_params(ks[2], d_ff, d, dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    # gate/up products stay in the compute dtype (bf16): silu is
    # numerically tame and fp32 intermediates here double the dominant
    # (B, S, d_ff) traffic (Sec. Perf, hillclimb A it4)
    g = jax.nn.silu(linear(x, p["w_gate"]))
    u = linear(x, p["w_up"])
    return linear(g * u, p["w_down"])
