"""Functional layer library (params = pytrees; scan-over-layers friendly)."""
