"""Rotary position embeddings (llama convention: rotate half)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32.  Rotates pairs
    (x[..., :D/2], x[..., D/2:]) per the llama convention."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs         # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                        # (..., S, 1, D/2)
    sin = sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)
