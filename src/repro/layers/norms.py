"""RMSNorm (the norm every assigned arch uses) -- fp32 statistics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm_params(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Variance in fp32 (a reduction -- cheap), scaling applied in the
    input dtype: avoids materializing fp32 copies of the (B, S, d)
    activation stream (Sec. Perf, hillclimb A it4)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * weight.astype(x.dtype)
