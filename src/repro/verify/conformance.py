"""Conformance checker: executed schedules must match the paper's algebra.

``check(plan)`` closes the loop the paper leaves implicit -- that the
equivariant map IS the schedule, with provable costs (Sec. 2.4) -- by
asserting three independent derivations of a plan's communication agree:

  1. **Structure** (the algebra): every emitted ppermute is a bijection;
     movement perms are torus translations (the movement homomorphism
     commutes with the torus action); the reified ``TorusProgram`` is
     byte-identical to the one derived from the plan's schedule; the
     Fig.-10 diagram equations hold; per-step single-copy memory holds.
  2. **Cost model** (the analytics): the virtual trace's movement words
     equal the schedule-derived word count, equal ``dist.api.estimate``'s
     closed form on the padded problem, and -- for square torus problems --
     the trace's link-words equal ``core.cost.torus_schedule_cost``.
     Measured words must also respect the Irony--Toledo--Tiskin bandwidth
     lower bound at the trace's own memory footprint.
  3. **Execution** (optional, ``measure=True``): the collectives the real
     shard_map lowering emits, captured by ``repro.verify.interceptor`` at
     the ``repro.dist._collectives`` seam, form exactly the trace's
     multiset -- kind, group, shard words, and permutation pairs.

Any disagreement raises ``ConformanceError`` naming the leg that broke.
``run_matrix`` sweeps strategy x mesh shape x {square, ragged, batched} x
dtype on the available (forced-host) devices -- the pytest ``conformance``
suite and ``benchmarks/run.py --conformance`` both drive it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost import bandwidth_lower_bound, torus_schedule_cost
from repro.core.schedule import (movement_equations_hold, perm_is_bijection,
                                 perm_translation)

from .trace import (CollectiveRecord, Trace, canonical_perm, padded_dims,
                    torus_single_copy_ok, trace_plan)


class ConformanceError(AssertionError):
    """An executed or reified schedule disagrees with the algebra/model."""


@dataclasses.dataclass(frozen=True)
class ConformanceReport:
    strategy: str
    mesh_size: int
    grid: Tuple[int, ...]
    padded: Tuple[int, int, int]
    words_per_node: float          # movement/gather/reduce phases
    link_words: Optional[float]    # torus strategies on square problems
    peak_node_words: float
    itt_bound: float
    measured: bool
    hlo_collective_bytes: Optional[float] = None


def _fail(leg: str, msg: str):
    raise ConformanceError(f"[{leg}] {msg}")


def _is_torus_family(plan) -> bool:
    return plan.torus is not None and plan.strategy != "cannon25d"


def _ring_translation(perm, t: int) -> Optional[int]:
    """Constant shift realized by a ring perm over Z_t, or None."""
    perm = tuple(perm)
    mu = None
    for s, d in perm:
        step = (int(d) - int(s)) % t
        if mu is None:
            mu = step
        elif step != mu:
            return None
    if mu not in (None, 0) and len(perm) != t:
        return None
    return mu if mu is not None else 0


def _xor_mask(perm, g: int) -> Optional[int]:
    """Nonzero XOR mask realized by a perm on Z_2^log2(g), or None.  The
    fat-tree exchange is the involution d -> d ^ mask: every pod moves
    (no fixed points, so the canonical perm has all g pairs) and the mask
    is a single constant (its highest bit names the deepest tree level
    crossed)."""
    perm = tuple(perm)
    masks = {int(s) ^ int(d) for s, d in perm}
    if len(masks) != 1:
        return None
    mask = masks.pop()
    if mask == 0 or len(perm) != g:
        return None
    return mask


def predicted_words_per_device(plan) -> float:
    """The analytic cost model's per-device movement words for ``plan`` on
    the padded problem.  Torus-family plans are priced from the schedule
    itself (the Sec.-2.4 functional: each variable set whose movement
    homomorphism is nonzero moves its block once per step); every standard
    strategy is priced by ``dist.api.estimate``'s closed form -- ``check``
    asserts the two derivations agree where both apply."""
    from repro.dist.api import STRATEGIES, estimate

    mp, np_, kp = padded_dims(plan)
    p = int(plan.mesh.size) if plan.mesh is not None else 1
    if plan.strategy == "local" or p <= 1:
        return 0.0
    if plan.torus is not None:
        if plan.strategy == "cannon25d":
            c, q, _ = plan.grid
        else:
            c, q = 1, plan.torus.q
        blocks = {
            "A": (mp // q) * (kp // (c * q)),
            "B": (kp // (c * q)) * (np_ // q),
            "C": (mp // q) * (np_ // q),
        }
        moves = plan.schedule.movements() if plan.schedule is not None else None
        if moves is None:
            _fail("structure", "torus plan without solvable movements")
        words = sum(
            (plan.torus.steps - 1) * blk
            for var, blk in blocks.items()
            if (moves[var][0] % q, moves[var][1] % q) != (0, 0)
        )
        if c > 1:
            words += 2 * (c - 1) / c * blocks["C"]
        return float(words)
    if plan.strategy in STRATEGIES:
        est = estimate(plan.strategy, mp, np_, kp, p, dtype_bytes=1,
                       grid=plan.grid or None)
        return float(est.comm_bytes)
    _fail("cost", f"no analytic prediction for strategy {plan.strategy!r}")


def memory_bound_words(plan) -> float:
    """Per-node memory bound, derived from single-copy *shares* (padded
    variable words / P) scaled by each variable's replication factor --
    independent of the tracer's working-set accounting, which ``check``
    compares against it.  Torus/ring families replicate nothing beyond the
    plan's pod factor; the broadcast family (SUMMA/pod25d) holds each
    operand gathered over one mesh axis and (pod25d) the full C partial
    per layer -- that IS its replication, and the bound prices it."""
    mp, np_, kp = padded_dims(plan)
    p = int(plan.mesh.size) if plan.mesh is not None else 1
    share_a = mp * kp / max(p, 1)
    share_b = kp * np_ / max(p, 1)
    share_c = mp * np_ / max(p, 1)
    overlap = bool(getattr(plan, "overlap", False))
    if plan.strategy == "fattree":
        # resident + column-gathered A slab, B shard + row-gathered panel,
        # one fp32 output block (the sliced k-slab reads the gathered
        # panel; it is not an extra resident copy in either derivation)
        s, qx, qy = plan.grid
        return float((1 + qy) * share_a + (1 + qx) * share_b + share_c)
    if plan.strategy in ("summa", "pod25d"):
        if len(plan.grid) >= 3:
            c, qx, qy = plan.grid
        elif plan.strategy == "pod25d":
            c, qx, qy = plan.grid[0], 1, 1
        else:
            c, (qx, qy) = 1, plan.grid
        if overlap and (qx > 1 or qy > 1):
            # decomposed-gather variant: the full B column panel plus
            # double-buffered A/B shards, the per-layer fp32 C partial,
            # and the resident B k-slab (the chain bodies' working set)
            return float(qx * share_b + 2 * share_a + 2 * share_b
                         + c * share_c + (kp // (c * qy)) * (np_ // qy))
        return float(qy * share_a + qx * share_b + c * share_c)
    if plan.strategy == "ring_ag":
        # fused: only one x-chunk resident per step -- true single copy
        return float(share_a + share_b + share_c)
    if plan.strategy == "ring_rs":
        # the full (m, n) partial product is resident before the scatter:
        # t-fold replication of C
        t = plan.grid[0] if plan.grid else p
        return float(share_a + share_b + t * share_c)
    bound = float(max(plan.replication, 1)) * (share_a + share_b + share_c)
    if overlap and plan.torus is not None:
        # double buffering keeps one extra copy of each moving operand
        if canonical_perm(plan.torus.step_a or ()):
            bound += share_a
        if canonical_perm(plan.torus.step_b or ()):
            bound += share_b
    return bound


def compare_records(expected: Sequence[CollectiveRecord],
                    measured: Sequence[CollectiveRecord]) -> None:
    """Exact multiset equality of collective records (phase annotations
    excluded); raises ``ConformanceError`` listing the divergence with
    multiplicities (so a dropped round of an otherwise-identical permute
    still names the key)."""
    from collections import Counter

    exp = Counter(r.key for r in expected)
    got = Counter(r.key for r in measured)
    if exp == got:
        return
    exp_only = sorted((exp - got).items())
    got_only = sorted((got - exp).items())
    _fail("interceptor",
          "executed collectives diverge from the schedule trace; "
          f"trace-only={exp_only[:3]!r} executed-only={got_only[:3]!r} "
          f"(trace {sum(exp.values())} records, "
          f"executed {sum(got.values())})")


def _check_structure(plan, trace: Trace) -> None:
    # movement vectors the *program* realizes, recovered from its perms --
    # a stationary variable has no movement record and contributes mu = 0
    executed_mus = {"A": (0, 0), "B": (0, 0), "C": (0, 0)}
    for rec in trace.records:
        if rec.kind != "ppermute":
            continue
        if not perm_is_bijection(rec.perm, rec.group):
            _fail("structure",
                  f"{rec.phase or 'executed'} perm for {rec.var or '?'} is "
                  f"not a bijection on {rec.group} devices")
        if rec.phase == "movement":
            if plan.torus is not None:
                q = math.isqrt(rec.group)
                mu = perm_translation(rec.perm, q)
                if mu is None:
                    _fail("structure",
                          f"movement perm for {rec.var} is not a torus "
                          "translation: the movement homomorphism does not "
                          "commute with the torus action")
                if rec.var:
                    executed_mus[rec.var] = mu
            elif plan.strategy == "fattree":
                if _xor_mask(rec.perm, rec.group) is None:
                    _fail("structure",
                          f"tree perm for {rec.var} is not an XOR-mask "
                          "involution on the pod axis (the Gray-order slab "
                          "walk is broken)")
            elif plan.strategy in ("ring_ag", "ring_rs"):
                if _ring_translation(rec.perm, rec.group) is None:
                    _fail("structure",
                          f"ring perm for {rec.var} is not a Z_t translation")
    if plan.schedule is not None and plan.torus is not None:
        # Fig.-10 equations against the executed mus (discriminating form:
        # a wrong-but-valid translation fails the diagram here)
        if not movement_equations_hold(plan.schedule, executed_mus):
            _fail("structure",
                  "Fig.-10 movement equations do not hold for the executed "
                  f"movement vectors {executed_mus}")
        from repro.plan.ir import TorusProgram

        if plan.torus != TorusProgram.from_schedule(plan.schedule):
            _fail("structure",
                  "reified TorusProgram does not match the plan's schedule "
                  "(wrong-permutation mutation?)")
        if not torus_single_copy_ok(plan.schedule):
            _fail("structure", "per-step single-copy memory bound violated")


def _check_cost(plan, trace: Trace) -> Tuple[float, Optional[float], float]:
    p = trace.mesh_size
    words_node = trace.movement_words() / p
    predicted = predicted_words_per_device(plan)
    if not math.isclose(words_node, predicted, rel_tol=1e-9, abs_tol=1e-6):
        _fail("cost",
              f"trace movement words/node {words_node} != analytic "
              f"prediction {predicted} for {plan.strategy}")

    link_words = None
    mp, np_, kp = trace.padded
    if _is_torus_family(plan) and plan.schedule is not None \
            and mp == np_ == kp:
        q = plan.torus.q
        link_words = trace.link_words(q)
        report = torus_schedule_cost(plan.schedule, mp)
        if not math.isclose(link_words, report.words_total,
                            rel_tol=1e-9, abs_tol=1e-6):
            _fail("cost",
                  f"trace link-words {link_words} != torus_schedule_cost "
                  f"{report.words_total} (hop counts diverge)")

    bound = memory_bound_words(plan)
    if trace.peak_node_words > bound + 1e-6:
        _fail("memory",
              f"peak per-node words {trace.peak_node_words} exceed "
              f"replication bound {bound}")

    n_eff = (mp * np_ * kp) ** (1.0 / 3.0)
    itt = bandwidth_lower_bound(n_eff, p, max(trace.peak_node_words, 1.0))
    if words_node + 1e-6 < itt:
        _fail("bound",
              f"measured {words_node} words/node beat the Irony-Toledo-"
              f"Tiskin bound {itt} -- the count is wrong")
    return words_node, link_words, itt


def _check_fattree_levels(plan, trace: Trace) -> None:
    """Per-tree-level conformance of a fat-tree plan -- three independent
    derivations of the words entering every tree level must agree exactly:

      1. the plan trace's movement ppermutes, bucketed by the level their
         XOR masks cross (``trace.tree_level_words``);
      2. the analytic closed form ``Estimate.tree_level_words`` on the
         padded problem;
      3. the wreath-product machine model itself:
         ``trace_fattree(FatTreeSchedule(log2 s))`` A events projected to
         pod (k-bit) coordinates, scaled from elements to slab words.

    The top level is additionally pinned to the paper's claim: only A
    crosses the root, moving exactly Mp x Kp words over the run."""
    from repro.core.fattree import FatTreeSchedule
    from repro.dist.api import estimate

    from .trace import fattree_a_level_words, trace_fattree, tree_level_words

    s = plan.grid[0]
    dt = max(s.bit_length() - 1, 1)
    mp, np_, kp = trace.padded
    traced = tree_level_words(trace)
    est = estimate("fattree", mp, np_, kp, trace.mesh_size, dtype_bytes=1,
                   grid=plan.grid, axes=plan.axes)
    machine = fattree_a_level_words(trace_fattree(FatTreeSchedule(dt)), dt)
    scale = mp * kp / float(s * s)
    for lvl in range(1, dt + 1):
        analytic = est.tree_level_words[lvl - 1]
        projected = machine[lvl] * scale
        if not (math.isclose(traced[lvl], analytic,
                             rel_tol=1e-9, abs_tol=1e-6)
                and math.isclose(traced[lvl], projected,
                                 rel_tol=1e-9, abs_tol=1e-6)):
            _fail("cost",
                  f"tree level {lvl} words diverge: trace={traced[lvl]} "
                  f"analytic={analytic} wreath-projection={projected}")
    if not math.isclose(traced[dt], float(mp * kp),
                        rel_tol=1e-9, abs_tol=1e-6):
        _fail("cost",
              f"root-level words {traced[dt]} != Mp*Kp {mp * kp}: the "
              "paper's only-A-crosses-the-top claim is violated")


def hlo_collective_bytes(plan, dtype=None) -> float:
    """Third measurement modality: compile the plan under jit and sum the
    collective bytes ``repro.roofline.hlo_stats`` sees in the optimized
    HLO.  XLA may fuse or re-associate collectives, so this leg checks
    presence/absence, not exact counts."""
    import jax
    import jax.numpy as jnp

    from repro.plan.lower_shard_map import _lower_shard_map
    from repro.roofline import hlo_stats

    dtype = dtype if dtype is not None else plan.out_dtype
    flat_m = plan.m * math.prod(plan.batch) if plan.batch else plan.m
    a = jnp.zeros((flat_m, plan.k), dtype)
    b = jnp.zeros((plan.k, plan.n), dtype)
    txt = jax.jit(_lower_shard_map(plan)).lower(a, b).compile().as_text()
    return hlo_stats.analyze(txt).coll_bytes


def check(plan, *, measure: bool = False, hlo: bool = False) -> ConformanceReport:
    """Full conformance of ``plan``: structure, cost model, and (optionally)
    the executed collectives and compiled HLO.  Raises ``ConformanceError``
    on the first broken leg; returns the report otherwise."""
    trace = trace_plan(plan)
    _check_structure(plan, trace)
    words_node, link_words, itt = _check_cost(plan, trace)
    if plan.strategy == "fattree":
        _check_fattree_levels(plan, trace)

    if measure:
        from .interceptor import measure_plan

        cap = measure_plan(plan)
        if not any(p_ is plan for p_ in cap.lowered_plans):
            _fail("interceptor", "lowering hook did not see the plan")
        compare_records(trace.records, cap.records)

    hlo_bytes = None
    if hlo:
        hlo_bytes = hlo_collective_bytes(plan)
        if (hlo_bytes > 0) != (trace.words_total() > 0):
            _fail("hlo",
                  f"compiled HLO collective bytes {hlo_bytes} inconsistent "
                  f"with trace words {trace.words_total()}")

    return ConformanceReport(
        strategy=plan.strategy, mesh_size=trace.mesh_size, grid=trace.grid,
        padded=trace.padded, words_per_node=words_node,
        link_words=link_words, peak_node_words=trace.peak_node_words,
        itt_bound=itt, measured=measure, hlo_collective_bytes=hlo_bytes,
    )


# ---------------------------------------------------------------------------
# The conformance matrix: strategy x mesh shape x case x dtype
# ---------------------------------------------------------------------------

_CATALOG: Tuple[Tuple[str, Tuple[int, ...], Tuple[str, ...]], ...] = (
    ("cannon", (2, 2), ("x", "y")),
    ("cannon", (3, 3), ("x", "y")),
    ("cannon", (4, 4), ("x", "y")),
    ("summa", (2, 2), ("x", "y")),
    ("summa", (2, 4), ("x", "y")),
    ("summa", (4, 4), ("x", "y")),
    ("pod25d", (4,), ("pod",)),
    ("pod25d", (2, 2, 2), ("pod", "x", "y")),
    ("pod25d", (2, 2, 4), ("pod", "x", "y")),
    ("cannon25d", (1, 2, 2), ("pod", "x", "y")),
    ("cannon25d", (2, 2, 2), ("pod", "x", "y")),
    ("cannon25d", (4, 2, 2), ("pod", "x", "y")),
    ("fattree", (2, 2, 2), ("tree", "x", "y")),
    ("fattree", (4, 2, 2), ("tree", "x", "y")),
    ("ring_ag", (4,), ("t",)),
    ("ring_ag", (2, 2), ("x", "y")),
    ("ring_ag", (8,), ("t",)),
    ("ring_rs", (4,), ("t",)),
    ("ring_rs", (2, 2), ("x", "y")),
    ("ring_rs", (8,), ("t",)),
)

CASES: Dict[str, Dict] = {
    "square": {"m": 24, "n": 24, "k": 24, "batch": ()},
    "ragged": {"m": 13, "n": 7, "k": 11, "batch": ()},
    "batched": {"m": 5, "n": 8, "k": 12, "batch": (3,)},
}


def matrix_cells(num_devices: int):
    """Catalog entries executable with ``num_devices`` devices."""
    return [c for c in _CATALOG if math.prod(c[1]) <= num_devices]


def _overlap_modes(strategy: str, shape: Tuple[int, ...]):
    """Overlap dimension of one matrix cell: strategies with both lowerings
    run staged AND overlapped; the rest run their single (default) form."""
    if strategy in ("cannon", "summa", "cannon25d"):
        return (False, True)
    if strategy == "pod25d" and len(shape) >= 3:
        return (False, True)
    return (None,)


def run_matrix(*, measure: bool = True, cases: Optional[Sequence[str]] = None,
               dtypes: Optional[Sequence] = None,
               num_devices: Optional[int] = None) -> List[Dict]:
    """Run the conformance matrix on the available devices; one result row
    per (strategy, mesh shape, case, dtype) cell.  Never raises -- failures
    are rows with ``ok=False`` so a sweep reports every broken cell."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.plan import build_plan

    devs = np.array(jax.devices())
    num_devices = len(devs) if num_devices is None else num_devices
    cases = tuple(cases) if cases is not None else tuple(CASES)
    dtypes = tuple(dtypes) if dtypes is not None else (jnp.float32,
                                                       jnp.bfloat16)
    rows: List[Dict] = []
    meshes: Dict[Tuple, object] = {}
    for strategy, shape, names in matrix_cells(num_devices):
        for case in cases:
            spec = CASES[case]
            for dtype in dtypes:
                for mode in _overlap_modes(strategy, shape):
                    row = {"strategy": strategy, "mesh": shape,
                           "case": case, "dtype": jnp.dtype(dtype).name,
                           "overlap": bool(mode), "ok": True,
                           "error": "", "words_per_node": 0.0}
                    try:
                        key = (shape, names)
                        if key not in meshes:
                            meshes[key] = jax.make_mesh(
                                shape, names,
                                devices=devs[:math.prod(shape)])
                        plan = build_plan(
                            spec["m"], spec["n"], spec["k"],
                            mesh=meshes[key], strategy=strategy,
                            batch=spec["batch"], a_dtype=dtype,
                            b_dtype=dtype, overlap=mode,
                        )
                        row["overlap"] = bool(plan.overlap)
                        rep = check(plan, measure=measure)
                        row["words_per_node"] = rep.words_per_node
                    except Exception as e:  # noqa: BLE001 -- reports all
                        row["ok"] = False
                        row["error"] = f"{type(e).__name__}: {e}"
                    rows.append(row)
    return rows
