"""Drift check: the measured machine vs the analytic model, continuously.

Two legs, both meant for CI (``benchmarks/run.py --drift``):

  1. **Collective drift** -- for a strategy x mesh sample, execute the real
     lowering with BOTH observers active: the ``repro.obs`` recorder at the
     dist seam and the ``repro.verify`` interceptor patched over it.  The
     obs multiset, the interceptor multiset, and the schedule trace must be
     *identical* (``CollectiveRecord.key`` granularity).  Any divergence
     means an instrumentation seam rotted or a lowering changed without its
     trace rule -- fail loudly.

  2. **Ranking drift** -- calibrate a fresh ``MachineProfile`` on the live
     machine and compare ``rank_mesh_strategies(profile=...)`` winners
     against a stored profile (when given) over a shape sample.  A flip is
     only reported when the fresh profile separates the two winners by more
     than ``flip_margin`` (relative seconds), so timing noise on a shared
     CI runner cannot flap the job; a genuine hardware/model change will
     clear the margin.

  3. **Tuning drift** -- when the stored profile embeds a ``repro.tune``
     ``TuningTable``, re-search each stored bucket fresh and, where the
     fresh winner's blocks differ, re-time the *stored* winner's blocks on
     the live machine.  A flip is reported only when the stored blocks are
     more than ``flip_margin`` slower than the fresh winner -- the same
     noise guard as the ranking leg, applied to kernel seconds.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

# (strategy, mesh shape, axis names) sample -- one cell per lowering family
DRIFT_CELLS: Tuple[Tuple[str, Tuple[int, ...], Tuple[str, ...]], ...] = (
    ("cannon", (2, 2), ("x", "y")),
    ("summa", (2, 2), ("x", "y")),
    ("ring_ag", (4,), ("t",)),
    ("ring_rs", (4,), ("t",)),
    ("cannon25d", (2, 2, 2), ("pod", "x", "y")),
    ("pod25d", (2, 2, 2), ("pod", "x", "y")),
    ("fattree", (2, 2, 2), ("tree", "x", "y")),
)

# (m, n, k) sample spanning the compute-bound / gather-cheap / reduce-cheap
# regimes where rankings genuinely differ
RANKING_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (4096, 4096, 4096),
    (64, 1024, 64),
    (256, 256, 1 << 16),
)


def measure_cell(strategy: str, mesh, m: int = 24, n: int = 24,
                 k: int = 24) -> Dict:
    """Execute one cell with obs + interceptor active and compare the three
    collective multisets (obs == interceptor == trace)."""
    import jax
    import jax.numpy as jnp

    from repro import obs
    from repro.plan import build_plan
    from repro.plan.lower_shard_map import _lower_shard_map
    from repro.verify.interceptor import intercept
    from repro.verify.trace import trace_plan

    # uncached plan + fresh lowering closure: shard_map must re-trace under
    # the active observers (see interceptor.measure_plan)
    plan = build_plan(m, n, k, mesh=mesh, strategy=strategy, use_cache=False)
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    with obs.observe() as rec:
        with intercept() as cap:
            with obs.span("plan.execute", strategy=strategy):
                jax.block_until_ready(_lower_shard_map(plan)(a, b))
    obs_ms = obs.collective_multiset(rec, strategy=strategy)
    int_ms = Counter(r.key for r in cap.records)
    trace_ms = Counter(r.key for r in trace_plan(plan).records)
    ok = obs_ms == int_ms == trace_ms
    row = {"strategy": strategy,
           "mesh": tuple(int(s) for s in plan.grid) or (int(mesh.size),),
           "ok": bool(ok),
           "collectives": int(sum(int_ms.values())),
           "error": ""}
    if not ok:
        row["error"] = (
            f"multiset divergence: obs-only={sorted((obs_ms - int_ms))[:3]} "
            f"interceptor-only={sorted((int_ms - obs_ms))[:3]} "
            f"trace-only={sorted((trace_ms - int_ms))[:3]}")
    return row


def ranking_drift(mesh, stored, fresh, *,
                  shapes: Sequence[Tuple[int, int, int]] = RANKING_SHAPES,
                  flip_margin: float = 0.1) -> List[Dict]:
    """Compare calibrated strategy winners under ``stored`` vs ``fresh``
    profiles; a flip only counts when the fresh profile separates the two
    winners by more than ``flip_margin`` relative seconds."""
    from repro.plan import rank_mesh_strategies

    rows: List[Dict] = []
    for m, n, k in shapes:
        r_stored = rank_mesh_strategies(m, n, k, mesh, profile=stored)
        r_fresh = rank_mesh_strategies(m, n, k, mesh, profile=fresh)
        top_s, top_f = r_stored[0].strategy, r_fresh[0].strategy
        flipped = False
        margin = 0.0
        if top_s != top_f:
            s_stored = fresh.seconds(
                next(e for e in r_fresh if e.strategy == top_s))
            s_fresh = fresh.seconds(r_fresh[0])
            margin = abs(s_stored - s_fresh) / max(s_fresh, 1e-12)
            flipped = margin > flip_margin
        rows.append({"shape": (m, n, k), "stored_top": top_s,
                     "fresh_top": top_f, "flipped": flipped,
                     "margin": margin})
    return rows


def tuning_drift(stored_table, *, flip_margin: float = 0.1, reps: int = 2,
                 max_entries: int = 4,
                 max_candidates: int = 8) -> List[Dict]:
    """Per-bucket re-measurement of a stored ``TuningTable``: fresh-search
    each stored bucket (bounded by ``max_entries``/``max_candidates`` for
    CI) and flag entries whose stored blocks have gone stale -- i.e. the
    stored winner re-timed on the live machine is more than ``flip_margin``
    slower than the fresh winner."""
    from repro.tune import time_candidate, tune_shape

    rows: List[Dict] = []
    for key, entry in list(stored_table.entries)[:max_entries]:
        dtype, bm, bn, bk = key
        fresh = tune_shape(bm, bn, bk, dtype, reps=reps,
                           max_candidates=max_candidates)
        stored_blocks = (entry.block_m, entry.block_n, entry.block_k,
                         entry.order)
        fresh_blocks = (fresh.block_m, fresh.block_n, fresh.block_k,
                        fresh.order)
        flipped = False
        margin = 0.0
        if stored_blocks != fresh_blocks:
            s_stored = time_candidate(bm, bn, bk, dtype, stored_blocks,
                                      reps=reps)
            margin = (s_stored - fresh.seconds) / max(fresh.seconds, 1e-12)
            flipped = margin > flip_margin
        rows.append({"bucket": (bm, bn, bk), "dtype": dtype,
                     "stored": entry.label, "fresh": fresh.label,
                     "flipped": flipped, "margin": margin})
    return rows


def check_drift(*, profile_path: Optional[str] = None,
                num_devices: Optional[int] = None,
                flip_margin: float = 0.1) -> Dict:
    """Run both drift legs on the available devices; returns a report dict
    with ``ok`` False when any collective multiset diverges or a stored
    profile would flip a ranking beyond the noise margin."""
    import jax
    import numpy as np

    from repro import obs

    devs = np.array(jax.devices())
    num_devices = len(devs) if num_devices is None else num_devices
    meshes: Dict[Tuple, object] = {}
    cells: List[Dict] = []
    for strategy, shape, names in DRIFT_CELLS:
        if math.prod(shape) > num_devices:
            continue
        key = (shape, names)
        if key not in meshes:
            meshes[key] = jax.make_mesh(shape, names,
                                        devices=devs[:math.prod(shape)])
        try:
            cells.append(measure_cell(strategy, meshes[key]))
        except Exception as e:  # noqa: BLE001 -- report every broken cell
            cells.append({"strategy": strategy, "mesh": shape, "ok": False,
                          "collectives": 0,
                          "error": f"{type(e).__name__}: {e}"})

    stored = obs.load_profile(profile_path) if profile_path else None

    ranking: List[Dict] = []
    fresh_json = None
    if num_devices >= 4:
        mesh22 = meshes.get(((2, 2), ("x", "y")))
        if mesh22 is None:
            mesh22 = jax.make_mesh((2, 2), ("x", "y"), devices=devs[:4])
        fresh = obs.probe_links(mesh22)
        fresh_json = fresh.to_json()
        if stored is not None:
            ranking = ranking_drift(mesh22, stored, fresh,
                                    flip_margin=flip_margin)

    tuning: List[Dict] = []
    if stored is not None and getattr(stored, "tuning", None) is not None:
        tuning = tuning_drift(stored.tuning, flip_margin=flip_margin)

    ok = all(c["ok"] for c in cells) and not any(
        r["flipped"] for r in ranking) and not any(
        r["flipped"] for r in tuning)
    return {"ok": ok, "cells": cells, "ranking": ranking,
            "tuning": tuning,
            "fresh_profile": fresh_json,
            "stored_profile_path": profile_path}
