"""repro.verify -- trace-level conformance for executed schedules.

The paper's claim is that equivariant maps *are* schedules with provable
time and communication costs; this package machine-checks it for every
program the repo executes, via three independent derivations of the same
communication:

  trace        -- a tracing interpreter replaying any ``SchedulePlan`` on a
                  virtual topology (torus, pod, ring; plus the fat-tree and
                  hex-array machine models of ``repro.core``)
  interceptor  -- a counting wrapper over the ``repro.dist._collectives``
                  seam capturing the collectives the real shard_map
                  lowering emits
  conformance  -- ``check(plan)``: trace == interceptor == analytic cost
                  model, plus the equivariance/bijection/translation
                  predicates and the Irony--Toledo--Tiskin bound;
                  ``run_matrix`` sweeps strategy x mesh x case x dtype

Every future lowering (fat-tree, hex) lands against this oracle instead of
only bitwise-output tests.

``drift`` adds the live-machine leg: the obs recorder, the interceptor,
and the trace compared on real executions, plus calibrated-ranking
stability against a stored machine profile (``check_drift``).
"""
from . import conformance, drift, interceptor, trace
from .conformance import (ConformanceError, ConformanceReport, check,
                          compare_records, hlo_collective_bytes,
                          matrix_cells, predicted_words_per_device,
                          run_matrix)
from .drift import check_drift, ranking_drift
from .interceptor import Capture, intercept, measure_plan
from .trace import (CollectiveRecord, MachineTrace, Trace, canonical_perm,
                    fattree_a_level_words, fattree_level_words, padded_dims,
                    trace_fattree, trace_hex, trace_plan, tree_level_words)

__all__ = [
    "conformance", "drift", "interceptor", "trace",
    "check_drift", "ranking_drift",
    "ConformanceError", "ConformanceReport", "check", "compare_records",
    "hlo_collective_bytes", "matrix_cells", "predicted_words_per_device",
    "run_matrix", "Capture", "intercept", "measure_plan",
    "CollectiveRecord", "MachineTrace", "Trace", "canonical_perm",
    "fattree_a_level_words", "fattree_level_words", "padded_dims",
    "trace_fattree", "trace_hex", "trace_plan", "tree_level_words",
]
