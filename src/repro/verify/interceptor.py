"""Counting interceptor for the real execution path.

``intercept()`` monkeypatches the ``repro.dist._collectives`` seam (the
single choke point every dist lowering rule's ppermute / all_gather / psum
goes through) and records one ``CollectiveRecord`` per collective the
shard_map body emits -- shapes and permutations captured at trace time, so
a single run of the lowered program yields the exact per-program collective
multiset regardless of how XLA later fuses or schedules it.

``measure_plan`` is the entry point conformance uses: it lowers ``plan``
through the real (uncached) lowering on its real mesh -- forced-host
multi-device CPU meshes in tests -- runs it once on zero operands, and
returns the captured records plus the plan identity confirmed through the
``repro.plan.on_lower`` hook.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import List

from .trace import CollectiveRecord, canonical_perm


@dataclasses.dataclass
class Capture:
    """Mutable record sink handed out by ``intercept``."""

    records: List[CollectiveRecord] = dataclasses.field(default_factory=list)
    lowered_plans: List = dataclasses.field(default_factory=list)

    def add(self, rec: CollectiveRecord) -> None:
        self.records.append(rec)


def _axis_group(axis_name) -> int:
    """Static size of the named-axis group a collective runs over; the
    ``psum(1, axis)`` idiom is concrete under shard_map tracing."""
    from jax import lax

    return int(lax.psum(1, axis_name))


def _shard_words(x) -> int:
    return int(math.prod(x.shape)) if getattr(x, "shape", None) else 1


@contextlib.contextmanager
def intercept():
    """Patch the dist collective seam; yields a ``Capture`` that fills with
    one record per collective traced while the context is active."""
    from repro.dist import _collectives as seam
    from repro.plan.lower_shard_map import on_lower

    cap = Capture()
    orig_ppermute = seam.ppermute
    orig_all_gather = seam.all_gather
    orig_psum = seam.psum

    def ppermute(x, axis_name, perm):
        cap.add(CollectiveRecord("ppermute", _axis_group(axis_name),
                                 _shard_words(x), canonical_perm(perm)))
        return orig_ppermute(x, axis_name, perm)

    def all_gather(x, axis_name, *, axis, tiled):
        cap.add(CollectiveRecord("all_gather", _axis_group(axis_name),
                                 _shard_words(x)))
        return orig_all_gather(x, axis_name, axis=axis, tiled=tiled)

    def psum(x, axis_name):
        cap.add(CollectiveRecord("psum", _axis_group(axis_name),
                                 _shard_words(x)))
        return orig_psum(x, axis_name)

    seam.ppermute, seam.all_gather, seam.psum = ppermute, all_gather, psum
    remove = on_lower(cap.lowered_plans.append)
    try:
        yield cap
    finally:
        remove()
        seam.ppermute = orig_ppermute
        seam.all_gather = orig_all_gather
        seam.psum = orig_psum


def measure_plan(plan, dtype=None) -> Capture:
    """Execute ``plan``'s real shard_map lowering once on zero operands of
    the folded 2-D problem and return the captured collective records.

    Exercises the genuine public ``lower_shard_map`` for the ``on_lower``
    hook wiring, then *executes* a freshly built (uncached) lowering: the
    body closures must be new objects so shard_map re-traces them under
    the active interceptor -- a closure memoized by an earlier lowering may
    already be traced and would emit nothing -- without evicting other
    plans' cached closures.  Operands default to the plan's ``out_dtype``
    so dtype-conditioned lowering paths are the ones measured.
    """
    import jax
    import jax.numpy as jnp

    from repro.plan.lower_shard_map import _lower_shard_map, lower_shard_map

    dtype = dtype if dtype is not None else plan.out_dtype
    flat_m = plan.m * math.prod(plan.batch) if plan.batch else plan.m
    a = jnp.zeros((flat_m, plan.k), dtype)
    b = jnp.zeros((plan.k, plan.n), dtype)
    with intercept() as cap:
        lower_shard_map(plan)  # public path: fires the on_lower hook
        out = _lower_shard_map(plan)(a, b)
        jax.block_until_ready(out)
    return cap
