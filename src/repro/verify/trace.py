"""Schedule-trace recorder: replay a ``SchedulePlan`` on a virtual topology.

The tracer is a second, independent interpreter of the plan IR: where
``repro.plan.lower_shard_map`` turns a plan into shard_map/ppermute calls,
``trace_plan`` turns the *same* plan into a step-by-step ``Trace`` of
collective records and per-block movement events -- derived purely from the
plan's placement/movement/collection permutations and shapes, never from
jax.  ``repro.verify.conformance`` then closes the triangle:

    trace records   ==  interceptor-measured collectives   (exact multiset)
    trace words     ==  analytic cost-model words           (exact)

Counting conventions (shared with ``repro.verify.interceptor``):

  ppermute    one shard per listed non-identity (src, dst) pair
  all_gather  each device in the group receives (g - 1) shards
  psum        2 * (g - 1) shards per group (bidirectional ring all-reduce)

Words are dtype-agnostic element counts, so the fp32 accumulator permutes
of the ring/torus programs compare cleanly across operand dtypes.

Besides plans, the tracer replays the two non-torus machine models of the
paper: ``trace_fattree`` walks ``core.fattree.FatTreeSchedule`` positions
into per-level link traffic, and ``trace_hex`` walks the systolic streams
of ``core.hexarray.HexSchedule`` -- both feed their direct unit tests and
the conformance checks on those models.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.cost import perm_link_words
from repro.core.fattree import tree_exchange_perm

Perm = Tuple[Tuple[int, int], ...]


def canonical_perm(perm) -> Perm:
    """Sorted non-identity (src, dst) pairs -- the comparable form of a
    ppermute permutation (identity pairs move no words)."""
    return tuple(sorted(
        (int(s), int(d)) for s, d in perm if int(s) != int(d)))


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective emitted by a lowered schedule.

    ``group`` is the size of the named-axis group the collective runs over;
    a mesh with P devices executes P / group independent copies of it.
    ``phase`` is a tracer-side annotation (placement / movement / collection
    / gather / reduce) that the interceptor cannot observe -- it is excluded
    from the comparison key.
    """

    kind: str                 # "ppermute" | "all_gather" | "psum"
    group: int
    shard_words: int
    perm: Optional[Perm] = None   # canonical, ppermute only
    phase: str = ""
    var: str = ""

    @property
    def key(self) -> Tuple:
        return (self.kind, self.group, self.shard_words, self.perm)

    def words_total(self, mesh_size: int) -> float:
        """Words this collective moves across the whole mesh."""
        copies = mesh_size / self.group
        if self.kind == "ppermute":
            return float(self.shard_words * len(self.perm or ()) * copies)
        if self.kind == "all_gather":
            return float(self.shard_words * (self.group - 1) * self.group
                         * copies)
        if self.kind == "psum":
            return float(2 * (self.group - 1) * self.shard_words * copies)
        raise ValueError(self.kind)


@dataclasses.dataclass(frozen=True)
class Trace:
    """The full communication trace of one lowered plan."""

    strategy: str
    mesh_size: int
    grid: Tuple[int, ...]
    padded: Tuple[int, int, int]       # (Mp, Np, Kp) after grid padding
    records: Tuple[CollectiveRecord, ...]
    peak_node_words: float             # per-node resident working set

    def words_total(self, phases: Optional[Tuple[str, ...]] = None) -> float:
        return sum(r.words_total(self.mesh_size) for r in self.records
                   if phases is None or r.phase in phases)

    def words_per_node(self, phases: Optional[Tuple[str, ...]] = None) -> float:
        return self.words_total(phases) / max(self.mesh_size, 1)

    def movement_words(self) -> float:
        """Words of the cost-model-visible phases: everything except the
        initial placement skew and the final collection restore (the
        analytic model prices steady-state movement only)."""
        return self.words_total(("movement", "gather", "reduce"))

    def link_words(self, q: int) -> float:
        """Torus link-words (words x minimal-route hops) of the movement
        phase -- comparable to ``core.cost.torus_schedule_cost``."""
        total = 0.0
        for r in self.records:
            if r.kind == "ppermute" and r.phase == "movement":
                copies = self.mesh_size / r.group
                total += perm_link_words(r.perm or (), q,
                                         r.shard_words) * copies
        return total

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.kind] = out.get(r.kind, 0) + 1
        return out


def _roundup(x: int, m: int) -> int:
    return -(-x // m) * m


def padded_dims(plan) -> Tuple[int, int, int]:
    """(Mp, Np, Kp) of the 2-D program the lowering actually runs: leading
    batch dims folded into the rows, operands zero-padded to the plan's
    block multiples (``pad_a`` and ``pad_b`` agree on k by construction)."""
    flat_m = plan.m * math.prod(plan.batch) if plan.batch else plan.m
    mp = _roundup(flat_m, plan.pad_a[0])
    kp = _roundup(plan.k, plan.pad_a[1])
    assert kp == _roundup(plan.k, plan.pad_b[0]), "inconsistent k padding"
    np_ = _roundup(plan.n, plan.pad_b[1])
    return mp, np_, kp


def _torus_records(prog, a_blk: int, b_blk: int, c_blk: int,
                   group: int) -> List[CollectiveRecord]:
    """Mirror of ``repro.dist.cannon.torus_program_body``: skew, steps - 1
    movement rounds (identity perms elided exactly as ``_permute`` elides
    them), then the collection restore."""
    recs: List[CollectiveRecord] = []

    def permute(perm, blk, phase, var):
        cp = canonical_perm(perm or ())
        if cp:
            recs.append(CollectiveRecord("ppermute", group, blk, cp,
                                         phase, var))

    permute(prog.skew_a, a_blk, "placement", "A")
    permute(prog.skew_b, b_blk, "placement", "B")
    for _ in range(prog.steps - 1):
        permute(prog.step_a, a_blk, "movement", "A")
        permute(prog.step_b, b_blk, "movement", "B")
        permute(prog.step_c, c_blk, "movement", "C")
    permute(prog.collect_c, c_blk, "collection", "C")
    return recs


def trace_plan(plan) -> Trace:
    """Replay ``plan`` on its virtual topology (torus, pod, or ring) and
    return the communication ``Trace`` the lowering must reproduce."""
    mp, np_, kp = padded_dims(plan)
    strategy = plan.strategy
    mesh_size = int(plan.mesh.size) if plan.mesh is not None else 1
    grid = tuple(plan.grid)
    overlap = bool(getattr(plan, "overlap", False))
    recs: List[CollectiveRecord] = []
    peak = 0.0

    def _ring(g: int) -> Perm:
        return canonical_perm([(d, (d + 1) % g) for d in range(g)])

    def _chain(group: int, shard: int, var: str) -> List[CollectiveRecord]:
        # the one-hop decomposition of a tiled all_gather: (g - 1) ring
        # ppermutes of one shard each -- identical words per device
        return [CollectiveRecord("ppermute", group, shard, _ring(group),
                                 "gather", var)
                for _ in range(group - 1)]

    def _torus_overlap_extra(prog, a_blk: int, b_blk: int) -> float:
        # the double-buffered body keeps step k and the prefetched step
        # k + 1 copy live together -- one extra block per moving operand
        extra = 0.0
        if canonical_perm(prog.step_a or ()):
            extra += a_blk
        if canonical_perm(prog.step_b or ()):
            extra += b_blk
        return extra

    if strategy == "local" or mesh_size <= 1:
        peak = float(mp * kp + kp * np_ + mp * np_)
        return Trace("local", max(mesh_size, 1), grid, (mp, np_, kp),
                     tuple(recs), peak)

    if plan.torus is not None and strategy != "cannon25d":
        q = plan.torus.q
        a_blk = (mp // q) * (kp // q)
        b_blk = (kp // q) * (np_ // q)
        c_blk = (mp // q) * (np_ // q)
        recs = _torus_records(plan.torus, a_blk, b_blk, c_blk, q * q)
        peak = float(a_blk + b_blk + c_blk)
        if overlap:
            peak += _torus_overlap_extra(plan.torus, a_blk, b_blk)
    elif strategy == "summa":
        qx, qy = grid
        a_shard = (mp // qx) * (kp // qy)
        b_shard = (kp // qx) * (np_ // qy)
        if overlap:
            # decomposed gathers: B chain-gathered over the columns, A
            # ring-walked over the rows -- same words, one-hop pieces
            recs = _chain(qx, b_shard, "B") + _chain(qy, a_shard, "A")
            # B panel + double-buffered A and B shards + fp32 acc + b slab
            peak = float(qx * b_shard + 2 * a_shard + 2 * b_shard
                         + (mp // qx) * (np_ // qy)
                         + (kp // qy) * (np_ // qy))
        else:
            recs = [
                CollectiveRecord("all_gather", qy, a_shard, None,
                                 "gather", "A"),
                CollectiveRecord("all_gather", qx, b_shard, None,
                                 "gather", "B"),
            ]
            # gathered row panel + column panel + output block
            peak = float((mp // qx) * kp + kp * (np_ // qy)
                         + (mp // qx) * (np_ // qy))
    elif strategy == "fattree":
        s, qx, qy = grid
        a_shard = (mp // qx) * (kp // (s * qy))
        b_shard = (kp // qx) * (np_ // (s * qy))
        c_shard = (mp // qx) * (np_ // (s * qy))
        # mirror of ``repro.dist.fattree.fattree_body``: one hoisted B
        # panel gather over the rows, then s super-steps, each an A slab
        # gather over the columns followed (except last) by the tree-axis
        # XOR exchange advancing every pod's resident slab
        recs = [CollectiveRecord("all_gather", qx, b_shard, None,
                                 "gather", "B")]
        for t in range(s):
            recs.append(CollectiveRecord("all_gather", qy, a_shard, None,
                                         "gather", "A"))
            if t < s - 1:
                recs.append(CollectiveRecord(
                    "ppermute", s, a_shard,
                    canonical_perm(tree_exchange_perm(s, t)),
                    "movement", "A"))
        # resident slab shard + gathered slab + B shard + gathered B
        # panel + fp32 output block (the sliced B k-slab is a view of the
        # gathered panel, not counted; see conformance.memory_bound_words)
        peak = float((1 + qy) * a_shard + (1 + qx) * b_shard + c_shard)
    elif strategy == "cannon25d":
        c, q, _ = grid
        a_blk = (mp // q) * (kp // (c * q))
        b_blk = (kp // (c * q)) * (np_ // q)
        c_blk = (mp // q) * (np_ // q)
        recs = _torus_records(plan.torus, a_blk, b_blk, c_blk, q * q)
        recs.append(CollectiveRecord("psum", c, c_blk, None, "reduce", "C"))
        peak = float(a_blk + b_blk + c_blk)
        if overlap:
            peak += _torus_overlap_extra(plan.torus, a_blk, b_blk)
    elif strategy == "pod25d":
        if len(grid) >= 3:
            c, qx, qy = grid
            a_shard = (mp // qx) * (kp // (c * qy))
            b_shard = (kp // (c * qx)) * (np_ // qy)
            c_shard = (mp // qx) * (np_ // qy)
            if overlap:
                recs = (_chain(qx, b_shard, "B") + _chain(qy, a_shard, "A")
                        + [CollectiveRecord("psum", c, c_shard, None,
                                            "reduce", "C")])
                peak = float(qx * b_shard + 2 * a_shard + 2 * b_shard
                             + c_shard + (kp // (c * qy)) * (np_ // qy))
            else:
                recs = [
                    CollectiveRecord("all_gather", qy, a_shard, None,
                                     "gather", "A"),
                    CollectiveRecord("all_gather", qx, b_shard, None,
                                     "gather", "B"),
                    CollectiveRecord("psum", c, c_shard, None, "reduce", "C"),
                ]
                peak = float((mp // qx) * (kp // c) + (kp // c) * (np_ // qy)
                             + c_shard)
        else:
            c = grid[0]
            recs = [CollectiveRecord("psum", c, mp * np_, None,
                                     "reduce", "C")]
            peak = float(mp * (kp // c) + (kp // c) * np_ + mp * np_)
    elif strategy in ("ring_ag", "ring_rs"):
        t = grid[0]
        ring = canonical_perm([(d, (d + 1) % t) for d in range(t)])
        if strategy == "ring_ag":
            shard = (mp // t) * kp
            var = "A"
            peak = float((mp // t) * kp + kp * (np_ // t) + mp * (np_ // t))
        else:
            shard = (mp // t) * np_
            var = "C"
            peak = float(mp * (kp // t) + (kp // t) * np_ + mp * np_)
        recs = [CollectiveRecord("ppermute", t, shard, ring,
                                 "movement", var)
                for _ in range(t - 1)]
    else:
        raise ValueError(f"no trace rule for strategy {strategy!r}")

    return Trace(strategy, mesh_size, grid, (mp, np_, kp), tuple(recs), peak)


# ---------------------------------------------------------------------------
# Non-torus machine models: fat-tree and hex-array traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MachineTrace:
    """Per-step (var, src, dst, words) events on a named machine model."""

    model: str
    num_nodes: int
    num_steps: int
    events: Tuple[Tuple[str, int, int, int], ...]  # (var, src, dst, words)

    def words_total(self) -> int:
        return sum(w for _, _, _, w in self.events)


def trace_fattree(sched) -> MachineTrace:
    """Step-by-step movement events of a ``FatTreeSchedule``: A and B
    relocations between consecutive time steps (C is stationary)."""
    n = sched.n
    events = []
    for time in range(sched.num_steps - 1):
        for a in range(n):
            for b in range(n):
                for var, src, dst in (
                    ("A", sched.pos_A(a, b, time), sched.pos_A(a, b, time + 1)),
                    ("B", sched.pos_B(a, b, time), sched.pos_B(a, b, time + 1)),
                ):
                    if src != dst:
                        events.append((var, src, dst, 1))
    return MachineTrace("fattree", sched.num_procs, sched.num_steps,
                        tuple(events))


def fattree_level_words(trace: MachineTrace, d: int) -> Dict[int, int]:
    """Per-level words x link-transits derived from a fat-tree trace: a
    message whose endpoints first differ at bit (L-1) transits 2 links at
    every level <= L -- the same accounting as
    ``core.fattree.FatTreeSchedule.link_traffic`` (its independent oracle)."""
    traffic = {lvl: 0 for lvl in range(1, 2 * d + 1)}
    for _, src, dst, words in trace.events:
        top = (src ^ dst).bit_length()
        for lvl in range(1, top + 1):
            traffic[lvl] += 2 * words
    return traffic


def fattree_a_level_words(trace: MachineTrace, d: int) -> Dict[int, int]:
    """A-movement words per *tree-of-pods* level, from the machine trace.

    The hierarchical lowering's tree axis is the k-dimension of the wreath
    recursion: pod p owns contraction slab p, so processor bit (2l + 1)
    (= k_l) of ``FatTreeSchedule`` is pod bit l of an s = 2^d tree axis.
    Projecting every A event to its k-bits and counting one-directional
    words whose endpoints first differ at pod bit (L - 1) yields the words
    entering tree level L -- B events project to a constant (B_jk never
    leaves its k) and drop out, reproducing "only A crosses the tree".
    Scaled by the slab words, this equals the plan trace's
    ``tree_level_words`` and the analytic ``Estimate.tree_level_words``.
    """

    def kbits(proc: int) -> int:
        k = 0
        for l in range(d):
            k |= ((proc >> (2 * l + 1)) & 1) << l
        return k

    words = {lvl: 0 for lvl in range(1, d + 1)}
    for var, src, dst, w in trace.events:
        if var != "A":
            continue
        ks, kd = kbits(src), kbits(dst)
        if ks == kd:
            continue
        top = (ks ^ kd).bit_length()
        for lvl in range(1, top + 1):
            words[lvl] += w
    return words


def tree_level_words(trace: Trace) -> Dict[int, float]:
    """Mesh-wide words entering each tree level of a fat-tree plan trace.

    Level L (1 = between sibling pods, log2(s) = across the root) is
    entered by a movement-ppermute pair whose endpoints first differ at
    pod bit (L - 1); the pair contributes its shard words to every level
    <= L (one-directional: the involution's two pairs are both counted,
    each once).  Comparable exactly to ``Estimate.tree_level_words`` on
    the padded dims and, scaled, to ``fattree_a_level_words``.
    """
    s = trace.grid[0]
    dt = max(s.bit_length() - 1, 1)
    copies = trace.mesh_size / s
    words = {lvl: 0.0 for lvl in range(1, dt + 1)}
    for r in trace.records:
        if r.kind != "ppermute" or r.phase != "movement" or r.group != s:
            continue
        for src, dst in (r.perm or ()):
            top = (src ^ dst).bit_length()
            for lvl in range(1, min(top, dt) + 1):
                words[lvl] += r.shard_words * copies
    return words


def hex_element_positions(sched, var: str, r: int, s: int):
    """(time, node) path of one stream element through the hex array.

    A_rs is touched by instructions (r, s, k) at times r+s+k; B and C
    likewise with their own index roles -- each element is live for q
    consecutive steps and its node at each is read straight off f."""
    q = sched.q
    out = []
    for free in range(q):
        if var == "A":
            node, t = sched.f(r, s, free)
        elif var == "B":
            node, t = sched.f(free, r, s)
        else:  # C_ki touched by (i, j, k) = (s, free, r)
            node, t = sched.f(s, free, r)
        out.append((t, node))
    out.sort()
    return out


def trace_hex(sched) -> MachineTrace:
    """Movement events of the hex systolic schedule: every stream element's
    hop between consecutive live steps, read off the equivariant map f --
    Kung's "direction, speed and timing" as a literal event list."""
    node_ids: Dict[Tuple[int, int], int] = {}

    def nid(node: Tuple[int, int]) -> int:
        return node_ids.setdefault(node, len(node_ids))

    events = []
    q = sched.q
    for var in ("A", "B", "C"):
        for r in range(q):
            for s in range(q):
                path = hex_element_positions(sched, var, r, s)
                for (t0, n0), (t1, n1) in zip(path, path[1:]):
                    assert t1 == t0 + 1, "stream element must move every step"
                    events.append((var, nid(n0), nid(n1), 1))
    return MachineTrace("hexarray", len(node_ids), sched.num_steps,
                        tuple(events))


def torus_single_copy_ok(schedule) -> bool:
    """Per-step memory invariant of a t = q torus schedule: at every time
    step each node holds exactly one block of each variable (the paper's
    three-words-per-node bound, blocked).  Follows from the placements
    being bijections and the movements being translations -- checked here
    by direct simulation so a mutated program cannot sneak through."""
    q = schedule.q
    for var in ("A", "B", "C"):
        pl = schedule.placement(var)
        mv = schedule.movement(var)
        if pl is None or mv is None:
            return False
        for step in range(schedule.t):
            occupied = set()
            for r in range(q):
                for s in range(q):
                    x = (int(pl[r, s, 0]) + step * mv[0]) % q
                    y = (int(pl[r, s, 1]) + step * mv[1]) % q
                    if (x, y) in occupied:
                        return False
                    occupied.add((x, y))
            if len(occupied) != q * q:
                return False
    return True


