"""repro.tune -- measured Pallas-kernel autotuning for the planner.

The calibrated cost model (PR 6/8) measures the *communication* side of
``calibrated_total_s``; this package measures the *compute* side.  A search
over the (block_m, block_n, block_k, order) space of ``kernels/matmul`` --
MXU-aligned, VMEM-feasible candidates, median-of-k timed -- lands winners
in a versioned :class:`TuningTable` keyed by device-kind x dtype x
padded-shape bucket.  ``build_plan(tuning=...)`` (or a ``MachineProfile``
with an embedded table) then ranks strategies and resolves overlap with
measured kernel seconds against calibrated comm seconds, and folds the
winning blocks into the plan's ``TilingPlan`` for ``lower_pallas``.
"""
from .search import (BLOCK_CANDIDATES, BLOCK_K_CANDIDATES, ORDERS,
                     VMEM_BUDGET_BYTES, Tuner, candidate_space,
                     time_candidate, tune_shape, tune_shapes)
from .table import (MXU, TUNING_SCHEMA, TunedBlocks, TuningTable, load_table,
                    pad_up, padded_flops, save_table, scaled_call_seconds,
                    shape_bucket, table_key)

__all__ = [
    "TUNING_SCHEMA", "MXU", "TunedBlocks", "TuningTable",
    "load_table", "save_table", "shape_bucket", "table_key", "pad_up",
    "padded_flops", "scaled_call_seconds",
    "Tuner", "candidate_space", "time_candidate", "tune_shape",
    "tune_shapes", "BLOCK_CANDIDATES", "BLOCK_K_CANDIDATES", "ORDERS",
    "VMEM_BUDGET_BYTES",
]
