"""The autotune search: measured candidate timing under MXU/VMEM constraints.

``candidate_space`` enumerates the (block_m, block_n, block_k, order)
candidates for a shape -- every block a multiple of the 128-wide MXU tile,
every working set within the same 96 MiB VMEM budget ``default_blocks``
targets, orders the paper's Z-order schedule vs the row-major baseline.
``tune_shape`` times each candidate at the shape's bucket (best of
``reps`` timed calls, ``jax.block_until_ready``, discarded compile+warmup
calls first) under ``tune.search`` obs spans and returns the winner as a
:class:`repro.tune.table.TunedBlocks`.

:class:`Tuner` is the planner-facing front end: a mutable search-on-miss
cache over table entries, hashable by identity so it can ride in plan-cache
keys and the serving harness's memoized closures.  ``serve.Server.warmup``
passes one in: every bucket's local kernel shapes get tuned at AOT-warmup
trace time, so the serve window never searches (the tuning twin of the
plan-cache 100%-hit-rate pin).
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, Optional, Tuple

from repro import obs
from repro.kernels.matmul.kernel import vmem_working_set_bytes

from .table import (MXU, Key, TunedBlocks, TuningTable, pad_up,
                    scaled_call_seconds, shape_bucket, table_key)

Candidate = Tuple[int, int, int, str]

BLOCK_CANDIDATES = (128, 256, 512)
BLOCK_K_CANDIDATES = (128, 256, 512, 1024, 2048)
ORDERS = ("zorder", "rowmajor")
# same budget default_blocks fits against: candidates never claim more VMEM
# than the heuristic would allow itself
VMEM_BUDGET_BYTES = 96 * 1024 * 1024


def candidate_space(m: int, n: int, k: int, dtype_bytes: int = 2, *,
                    out_dtype_bytes: Optional[int] = None,
                    max_candidates: Optional[int] = None
                    ) -> Tuple[Candidate, ...]:
    """Every legal candidate for an (m, k) x (k, n) call: MXU-aligned
    blocks no larger than the padded dims, VMEM-feasible at the given byte
    widths, in both traversal orders.  Shapes below one tile run the jnp
    reference kernel, where blocks are moot -- a single canonical candidate.
    ``max_candidates`` stride-samples a deterministic subset (largest
    footprints first) for bounded CI searches."""
    if min(m, n, k) < MXU:
        return ((MXU, MXU, MXU, "zorder"),)
    pm, pn, pk = pad_up(m), pad_up(n), pad_up(k)
    cands = []
    for bm in BLOCK_CANDIDATES:
        if bm > pm:
            continue
        for bn in BLOCK_CANDIDATES:
            if bn > pn:
                continue
            for bk in BLOCK_K_CANDIDATES:
                if bk > pk:
                    continue
                if vmem_working_set_bytes(
                        bm, bn, bk, dtype_bytes,
                        out_dtype_bytes) > VMEM_BUDGET_BYTES:
                    continue
                for order in ORDERS:
                    cands.append((bm, bn, bk, order))
    if max_candidates is not None and 0 < max_candidates < len(cands):
        cands.sort(key=lambda c: (-(c[0] * c[1] * c[2]), c[3]))
        step = len(cands) / max_candidates
        cands = [cands[int(i * step)] for i in range(max_candidates)]
    return tuple(cands)


def time_candidate(m: int, n: int, k: int, dtype, cand: Candidate, *,
                   reps: int = 3, interpret: Optional[bool] = None) -> float:
    """Best wall seconds of one kernel call with ``cand``'s blocks/order:
    two calls compile and warm (discarded), then the min of ``reps`` timed
    ``block_until_ready`` calls -- min, not median, because dispatch noise
    is strictly additive and heavy-tailed, so the fastest rep is the least
    contaminated estimate of the kernel itself.  ``interpret`` defaults to
    the backend's need (Pallas interpret mode off TPU/GPU)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.matmul import matmul

    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    bm, bn, bk, order = cand
    a = jnp.ones((m, k), jnp.dtype(dtype))
    b = jnp.ones((k, n), jnp.dtype(dtype))

    def run():
        return matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                      order=order, interpret=interpret)

    # compile + first dispatches, discarded: the first post-compile calls
    # still carry cold caches and would inflate the first candidate tried
    jax.block_until_ready(run())
    jax.block_until_ready(run())
    ts = []
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        ts.append(time.perf_counter() - t0)
    return float(min(ts))


def tune_shape(m: int, n: int, k: int, dtype="bfloat16", *,
               reps: int = 3, max_candidates: Optional[int] = None,
               interpret: Optional[bool] = None) -> TunedBlocks:
    """Search the candidate space at the shape's bucket and return the
    winner.  Timing happens at the *bucket* shape, so every shape sharing
    the bucket shares one honest measurement."""
    import jax.numpy as jnp

    dt = jnp.dtype(dtype)
    bucket = shape_bucket(m, n, k)
    cands = candidate_space(*bucket, dt.itemsize,
                            max_candidates=max_candidates)
    best: Optional[Candidate] = None
    best_t = float("inf")
    with obs.span("tune.search", m=m, n=n, k=k, dtype=dt.name,
                  bucket="x".join(str(x) for x in bucket),
                  candidates=len(cands)):
        for cand in cands:
            t = time_candidate(*bucket, dt.name, cand, reps=reps,
                               interpret=interpret)
            if obs.enabled():
                obs.histogram("tune.candidate_us").observe(t * 1e6)
            if t < best_t:
                best, best_t = cand, t
        if obs.enabled():
            obs.counter("tune.searches").inc()
    return TunedBlocks(block_m=best[0], block_n=best[1], block_k=best[2],
                       order=best[3], seconds=best_t, bucket=bucket)


class Tuner:
    """Search-on-miss front end over tuning entries (see module docstring).

    Deliberately NOT a dataclass: hashable by object identity, so one live
    tuner can sit in plan-cache keys and ``functools.lru_cache``'d serving
    closures while its entry dict and stats mutate underneath."""

    def __init__(self, *, table: Optional[TuningTable] = None,
                 reps: int = 3, max_candidates: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 device_kind: Optional[str] = None):
        self._entries: Dict[Key, TunedBlocks] = (
            dict(table.entries) if table is not None else {})
        self.reps = reps
        self.max_candidates = max_candidates
        self.interpret = interpret
        self._device_kind = device_kind
        self.stats: Dict[str, int] = {"hits": 0, "misses": 0, "searches": 0}

    def device_kind(self) -> str:
        if self._device_kind is None:
            import jax

            self._device_kind = jax.default_backend()
        return self._device_kind

    def keys(self) -> Tuple[Key, ...]:
        return tuple(self._entries)

    def lookup_key(self, key: Key, count: bool = True) -> Optional[TunedBlocks]:
        entry = self._entries.get(key)
        if count:
            self.stats["hits" if entry is not None else "misses"] += 1
        return entry

    def lookup(self, m: int, n: int, k: int, dtype: str = "bfloat16",
               count: bool = True) -> Optional[TunedBlocks]:
        return self.lookup_key(table_key(m, n, k, dtype), count=count)

    def entry_for(self, m: int, n: int, k: int,
                  dtype: str = "bfloat16") -> TunedBlocks:
        """The bucket's entry, searching (and caching the winner) on miss."""
        key = table_key(m, n, k, dtype)
        entry = self._entries.get(key)
        if entry is not None:
            self.stats["hits"] += 1
            return entry
        self.stats["misses"] += 1
        self.stats["searches"] += 1
        entry = tune_shape(m, n, k, dtype, reps=self.reps,
                           max_candidates=self.max_candidates,
                           interpret=self.interpret)
        self._entries[key] = entry
        return entry

    def compute_seconds(self, m: int, n: int, k: int,
                        dtype: str = "bfloat16") -> float:
        """Measured seconds of one (m, k) x (k, n) call -- never None: a
        live tuner searches the bucket on demand."""
        return scaled_call_seconds(self.entry_for(m, n, k, dtype), m, n, k)

    def table(self) -> TuningTable:
        """Frozen snapshot of the current entries for persistence/embedding
        (``MachineProfile.tuning``)."""
        from datetime import datetime, timezone

        return TuningTable(
            device_kind=self.device_kind(),
            entries=tuple(sorted(self._entries.items())),
            created=datetime.now(timezone.utc).isoformat())


def tune_shapes(shapes: Iterable[Tuple[int, int, int]], dtype="bfloat16", *,
                reps: int = 3, max_candidates: Optional[int] = None,
                interpret: Optional[bool] = None) -> TuningTable:
    """One-call batch search (``perf_probe --tune`` uses this): tune every
    shape's bucket and return the frozen table."""
    tuner = Tuner(reps=reps, max_candidates=max_candidates,
                  interpret=interpret)
    for m, n, k in shapes:
        tuner.entry_for(m, n, k, dtype=dtype)
    return tuner.table()
