"""Versioned kernel-tuning tables: measured Pallas matmul winners on disk.

A :class:`TuningTable` is what an autotune run (``repro.tune.search`` /
``python -m repro.launch.perf_probe --tune``) persists: for each
device-kind x dtype x padded-shape bucket, the winning
(block_m, block_n, block_k, order) candidate and its measured median
seconds at the bucket shape.  The planner consumes entries two ways:

  * ``compute_seconds(m, n, k, dtype)`` -- the measured kernel time scaled
    to the call's padded FLOPs.  ``build_plan(tuning=...)`` substitutes it
    for the peak-FLOPs compute term of ``core.cost.calibrated_total_s``,
    so strategy ranking and the overlap decision compare *measured*
    compute against calibrated communication.
  * ``entry_for(m, n, k, dtype)`` -- the winning blocks themselves, which
    ``build_plan`` folds into the plan's ``TilingPlan`` so
    ``lower_pallas`` runs them.

Shapes are bucketed (:func:`shape_bucket`: pad each dim to the 128-wide
MXU tile, then round up to a power of two) so nearby shapes share one
entry.  Tables are frozen/hashable (they participate in the plan-cache
key) with lookup hit/miss counters in a non-compared ``stats`` field, and
serialize to schema-versioned JSON exactly like
``repro.obs.profile.MachineProfile`` (``save_table``/``load_table``,
newer-schema rejection).  This module is pure stdlib on purpose: the
profile loader imports it lazily without dragging in jax.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

TUNING_SCHEMA = 1

MXU = 128  # systolic tile edge: every block and bucket dim is a multiple

Key = Tuple[str, int, int, int]  # (dtype name, bucket m, bucket n, bucket k)


def pad_up(x: int, mult: int = MXU) -> int:
    """``x`` rounded up to a positive multiple of ``mult`` (the kernel pads
    ragged shapes to block multiples; the tile is the floor)."""
    return max(((int(x) + mult - 1) // mult) * mult, mult)


def shape_bucket(m: int, n: int, k: int) -> Tuple[int, int, int]:
    """The padded-shape bucket of a call: each dim MXU-padded then rounded
    up to a power of two, so e.g. (300, 128, 200) and (290, 100, 140) share
    the (512, 128, 256) entry."""

    def b(x: int) -> int:
        p = pad_up(x)
        return 1 << (p - 1).bit_length()

    return (b(m), b(n), b(k))


def table_key(m: int, n: int, k: int, dtype: str) -> Key:
    """The lookup key of a call: dtype name x padded-shape bucket (the
    device kind is the table's own identity, one table per device kind)."""
    return (str(dtype),) + shape_bucket(m, n, k)


def padded_flops(m: int, n: int, k: int) -> float:
    """FLOPs the kernel actually executes for an (m, k) x (k, n) call:
    2 m n k over the MXU-padded dims (cf. the ``kernel.pad_waste`` metric)."""
    return 2.0 * pad_up(m) * pad_up(n) * pad_up(k)


@dataclasses.dataclass(frozen=True)
class TunedBlocks:
    """One search winner: the blocks/order to run and the measured median
    seconds of one kernel call at ``bucket`` shape."""

    block_m: int
    block_n: int
    block_k: int
    order: str
    seconds: float
    bucket: Tuple[int, int, int]

    @property
    def bucket_flops(self) -> float:
        bm, bn, bk = self.bucket
        return 2.0 * bm * bn * bk

    @property
    def label(self) -> str:
        return f"{self.block_m}x{self.block_n}x{self.block_k}/{self.order}"


def scaled_call_seconds(entry: TunedBlocks, m: int, n: int, k: int) -> float:
    """``entry.seconds`` (measured at the bucket shape) scaled to one
    (m, k) x (k, n) call's padded FLOPs -- constant achieved FLOP rate
    within a bucket."""
    return entry.seconds * (padded_flops(m, n, k) / entry.bucket_flops)


def _new_stats() -> Dict[str, int]:
    return {"hits": 0, "misses": 0}


@dataclasses.dataclass(frozen=True)
class TuningTable:
    """Frozen winners for one device kind (see module docstring).

    ``stats`` counts lookups (hit/miss) without participating in eq/hash,
    so a table in a plan-cache key still accumulates the serve-window
    accounting ``repro.serve.Server.cache_report`` exposes."""

    device_kind: str
    entries: Tuple[Tuple[Key, TunedBlocks], ...] = ()
    created: str = ""
    schema: int = TUNING_SCHEMA
    stats: Dict[str, int] = dataclasses.field(
        default_factory=_new_stats, compare=False, repr=False)

    def __post_init__(self):
        object.__setattr__(self, "_idx", dict(self.entries))

    def keys(self) -> Tuple[Key, ...]:
        return tuple(k for k, _ in self.entries)

    def lookup_key(self, key: Key, count: bool = True) -> Optional[TunedBlocks]:
        entry = self._idx.get(key)
        if count:
            self.stats["hits" if entry is not None else "misses"] += 1
        return entry

    def lookup(self, m: int, n: int, k: int, dtype: str = "bfloat16",
               count: bool = True) -> Optional[TunedBlocks]:
        return self.lookup_key(table_key(m, n, k, dtype), count=count)

    def entry_for(self, m: int, n: int, k: int,
                  dtype: str = "bfloat16") -> Optional[TunedBlocks]:
        """Lookup-only twin of ``Tuner.entry_for`` (no search on miss), so
        frozen tables and live tuners are interchangeable in the planner."""
        return self.lookup(m, n, k, dtype)

    def compute_seconds(self, m: int, n: int, k: int,
                        dtype: str = "bfloat16") -> Optional[float]:
        """Measured seconds of one (m, k) x (k, n) kernel call, or None
        when the bucket has no entry (the planner then falls back to the
        peak-FLOPs roofline)."""
        entry = self.lookup(m, n, k, dtype)
        return None if entry is None else scaled_call_seconds(entry, m, n, k)

    def with_entry(self, m: int, n: int, k: int, dtype: str,
                   entry: TunedBlocks) -> "TuningTable":
        """Functional update (tests doctor tables with it): a new table
        with the bucket's entry replaced/added, stats reset."""
        key = table_key(m, n, k, dtype)
        kept = tuple((kk, e) for kk, e in self.entries if kk != key)
        return dataclasses.replace(
            self, entries=tuple(sorted(kept + ((key, entry),))),
            stats=_new_stats())

    def to_json(self) -> Dict:
        return {
            "schema": self.schema,
            "device_kind": self.device_kind,
            "created": self.created,
            "entries": [
                {"dtype": key[0], "bucket": list(key[1:]),
                 "block_m": e.block_m, "block_n": e.block_n,
                 "block_k": e.block_k, "order": e.order,
                 "seconds": e.seconds}
                for key, e in self.entries
            ],
        }

    @classmethod
    def from_json(cls, obj: Dict) -> "TuningTable":
        schema = int(obj.get("schema", 0))
        if schema > TUNING_SCHEMA:
            raise ValueError(
                f"tuning table schema {schema} is newer than supported "
                f"{TUNING_SCHEMA}; re-run the autotune search")
        entries = []
        for rec in obj.get("entries", []):
            bucket = tuple(int(x) for x in rec["bucket"])
            key = (str(rec["dtype"]),) + bucket
            entries.append((key, TunedBlocks(
                block_m=int(rec["block_m"]), block_n=int(rec["block_n"]),
                block_k=int(rec["block_k"]), order=str(rec["order"]),
                seconds=float(rec["seconds"]), bucket=bucket)))
        return cls(
            device_kind=obj.get("device_kind", "unknown"),
            entries=tuple(sorted(entries)),
            created=obj.get("created", ""),
            schema=schema or TUNING_SCHEMA,
        )


def save_table(table: TuningTable, path: str) -> str:
    with open(path, "w") as f:
        json.dump(table.to_json(), f, indent=1, sort_keys=True)
    return path


def load_table(path: str) -> TuningTable:
    with open(path) as f:
        return TuningTable.from_json(json.load(f))
