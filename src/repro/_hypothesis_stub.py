"""Minimal, dependency-free stand-in for the slice of the hypothesis API
the test-suite uses: ``given``, ``settings``, ``assume``, and
``strategies.{integers, sampled_from, tuples, data}``.

Installed by ``tests/conftest.py`` ONLY when the real hypothesis package is
absent (the declared dev-dependency in pyproject.toml is preferred).  It
does deterministic pseudo-random sampling seeded per test -- no shrinking,
no database, no health checks -- which keeps the property tests meaningful
and reproducible in hermetic environments.
"""
from __future__ import annotations

import random
import sys
import types
import zlib


class _Unsatisfied(Exception):
    """Raised by assume() to discard the current example."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(100):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied()
        return SearchStrategy(draw)

    def example(self):
        return self._draw(random.Random(0))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))])


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s._draw(rng) for s in strategies))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.randrange(2)))


def lists(elements: SearchStrategy, *, min_size=0, max_size=10) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: [elements._draw(rng)
                     for _ in range(rng.randint(min_size, max_size))])


class DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy._draw(self._rng)


def data() -> SearchStrategy:
    return SearchStrategy(lambda rng: DataObject(rng))


class settings:
    """Decorator recording max_examples; deadline/suppress args ignored."""

    def __init__(self, max_examples: int = 100, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*given_args, **given_kwargs):
    def decorate(fn):
        # NOTE: no functools.wraps -- pytest follows __wrapped__ into the
        # original signature and would demand the property args as fixtures.
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", None)
            n = cfg.max_examples if cfg else 25
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(n * 4):
                if ran >= n:
                    break
                try:
                    pos = tuple(s._draw(rng) for s in given_args)
                    kw = {k: s._draw(rng) for k, s in given_kwargs.items()}
                    fn(*args, *pos, **kw, **kwargs)
                    ran += 1
                except _Unsatisfied:
                    continue
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.is_hypothesis_test = True
        return wrapper
    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "tuples", "booleans", "lists",
                 "data", "SearchStrategy"):
        setattr(strat, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = strat
    hyp.__is_repro_stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
