"""Version shims for the jax API surface this repo targets.

The codebase (tests included) is written against the modern spelling
``jax.shard_map`` / ``jax.make_mesh``.  On older installed jax (0.4.x)
``shard_map`` still lives in ``jax.experimental.shard_map``; installing the
alias keeps every call site on the new spelling without touching them.

``shard_map`` here defaults ``check_rep=False``: the dist engines produce
outputs whose replication (e.g. a ring all-gather that ends fully written on
every device) cannot be statically inferred by the checker.
"""
from __future__ import annotations

import functools

import jax

try:  # modern jax: the real thing
    _native_shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:
    _native_shard_map = None

if _native_shard_map is None:
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    @functools.wraps(_experimental_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False, **kwargs):
        kwargs.pop("check_vma", None)  # newer-jax spelling of check_rep
        return _experimental_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_rep, **kwargs,
        )
else:
    shard_map = _native_shard_map


def install() -> None:
    """Idempotently expose ``jax.shard_map`` on jax versions that lack it."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
