"""Deterministic synthetic token pipeline.

Sequences mix a learnable affine-chain signal (next = a*cur + b mod V with
probability ``signal``) with uniform noise, so small-model training shows a
real loss drop below ln(V) while remaining fully deterministic: batch
content is a pure function of (seed, step, position), independent of worker
count -- the property a production loader must have for elastic restarts
(the restored run replays the exact token stream).

``device_put_batch`` builds the globally-sharded arrays per mesh; on a real
multi-host cluster the same code path feeds per-host shards through
``jax.make_array_from_process_local_data``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.sharding import resolve_axis


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    signal: float = 0.9          # probability of the learnable transition
    mult: int = 31
    add: int = 17


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=(cfg.seed << 32) | step))


def synth_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """tokens/labels (global_batch, seq_len) int32; labels = next token."""
    rng = _batch_rng(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len + 1, cfg.vocab_size
    toks = np.empty((b, s), dtype=np.int64)
    toks[:, 0] = rng.integers(0, v, size=b)
    noise = rng.integers(0, v, size=(b, s))
    use_noise = rng.random((b, s)) > cfg.signal
    for t in range(1, s):
        chain = (toks[:, t - 1] * cfg.mult + cfg.add) % v
        toks[:, t] = np.where(use_noise[:, t], noise[:, t], chain)
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


def batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synth_batch(cfg, step)
        step += 1


def device_put_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh]) -> Dict:
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    batch_axes = resolve_axis("batch", mesh)
    out = {}
    for k, v in batch.items():
        spec = P(batch_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
