from repro.data.pipeline import DataConfig, batch_iterator, device_put_batch, synth_batch

__all__ = ["DataConfig", "batch_iterator", "device_put_batch", "synth_batch"]
