"""Inter-chip lowering: a ``SchedulePlan`` as a shard_map/ppermute program.

Each strategy is one lowering *rule* that composes
  pad -> shard_map(body) -> slice
where the body comes from the dist modules (``torus_body`` for anything
with a ``TorusSchedule``, the ring chains from ``repro.dist.ring``, the
all-gather / pod-reduce bodies from ``repro.dist.summa`` /
``repro.dist.pod25d``) and the per-device block multiply comes from the
plan's tiling via ``lower_pallas``.

``execute_plan`` adds the batching layer: leading batch dims of the left
operand are folded into the row dimension before the 2-D program runs
(exact -- it is the same global matmul with m' = prod(batch) * m); a
batched right operand is handled per batch element.
"""
from __future__ import annotations

import functools
import math

import jax
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.dist._util import pad_to
from repro.dist.cannon import (torus_program_body,
                               torus_program_body_overlapped)
from repro.dist.fattree import fattree_body
from repro.dist.pod25d import (cannon25d_body, pod25d_slab_body,
                               pod25d_summa_body,
                               pod25d_summa_overlapped_body)
from repro.dist.ring import ring_ag_matmul, ring_rs_matmul
from repro.dist.summa import summa_body, summa_overlapped_body
from repro.jax_compat import shard_map

from .ir import SchedulePlan
from .lower_pallas import lower_pallas


# Lowering observers: ``repro.verify`` hooks here to learn which plan is
# behind the collectives its interceptor counts.  Callbacks receive the
# plan on EVERY lowering request (cached or not).
_LOWER_OBSERVERS = []


def on_lower(callback):
    """Register ``callback(plan)`` to fire on each ``lower_shard_map`` call;
    returns a zero-argument unregister function."""
    _LOWER_OBSERVERS.append(callback)

    def remove():
        try:
            _LOWER_OBSERVERS.remove(callback)
        except ValueError:
            pass

    return remove


def _notify_lower(plan: SchedulePlan) -> None:
    for cb in tuple(_LOWER_OBSERVERS):
        cb(plan)


def lower_shard_map(plan: SchedulePlan):
    """Compile ``plan`` to a callable executing one global 2-D matmul
    (m, k) x (k, n) -> (m, n) as the planned shard_map/ppermute program.

    Memoized per plan (``SchedulePlan`` is frozen, and hashable whenever
    its mesh is -- always true for jax meshes): repeated dispatches of a
    cached plan reuse the compiled closure instead of rebuilding bodies --
    together with the plan cache this makes a repeat ``symmetric_matmul``
    call pure dictionary lookups down to the jit boundary.  Plans built on
    unhashable duck-typed meshes (tests) lower uncached."""
    _notify_lower(plan)
    with obs.span("plan.lower", strategy=plan.strategy,
                  overlap=plan.overlap):
        try:
            return _lower_shard_map_cached(plan)
        except TypeError:
            return _lower_shard_map(plan)


@functools.lru_cache(maxsize=256)
def _lower_shard_map_cached(plan: SchedulePlan):
    return _lower_shard_map(plan)


def _lower_shard_map(plan: SchedulePlan):
    local_fn = lower_pallas(plan)
    out_dtype = plan.out_dtype

    if plan.strategy == "local" or plan.mesh is None or plan.mesh.size == 1:
        return lambda a, b: local_fn(a, b, out_dtype=out_dtype)

    mesh = plan.mesh

    if plan.torus is not None and plan.strategy != "cannon25d":
        # cannon / any valid 2-D torus solution: execute the reified program
        ax, ay = plan.axes
        body_fn = (torus_program_body_overlapped if plan.overlap
                   else torus_program_body)
        body = body_fn(plan.torus, ax, ay, local_fn=local_fn)
        f = shard_map(
            lambda ab, bb: body(ab, bb).astype(out_dtype),
            mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay)),
            out_specs=P(ax, ay),
        )
        return _padded(f, plan)

    if plan.strategy == "summa":
        ax, ay = plan.axes
        summa_fn = summa_overlapped_body if plan.overlap else summa_body
        f = shard_map(
            summa_fn(ax, ay, out_dtype, local_fn=local_fn),
            mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay)),
            out_specs=P(ax, ay),
        )
        return _padded(f, plan)

    if plan.strategy == "fattree":
        tr, ax, ay = plan.axes
        f = shard_map(
            fattree_body(tr, ax, ay, plan.grid[0], out_dtype,
                         local_fn=local_fn),
            mesh=mesh,
            in_specs=(P(ax, (tr, ay)), P(ax, (tr, ay))),
            out_specs=P(ax, (tr, ay)),
        )
        return _padded(f, plan)

    if plan.strategy == "cannon25d":
        pod, ax, ay = plan.axes
        f = shard_map(
            cannon25d_body(pod, ax, ay, plan.torus, out_dtype,
                           local_fn=local_fn, overlap=plan.overlap),
            mesh=mesh,
            in_specs=(P(ax, (pod, ay)), P((pod, ax), ay)),
            out_specs=P(ax, ay),
        )
        return _padded(f, plan)

    if plan.strategy == "pod25d":
        pod = plan.axes[0]
        if len(plan.axes) >= 3:
            ax, ay = plan.axes[1], plan.axes[2]
            pod_fn = (pod25d_summa_overlapped_body if plan.overlap
                      else pod25d_summa_body)
            f = shard_map(
                pod_fn(pod, ax, ay, out_dtype, local_fn=local_fn),
                mesh=mesh,
                in_specs=(P(ax, (pod, ay)), P((pod, ax), ay)),
                out_specs=P(ax, ay),
            )
        else:
            f = shard_map(
                pod25d_slab_body(pod, out_dtype, local_fn=local_fn),
                mesh=mesh,
                in_specs=(P(None, pod), P(pod, None)),
                out_specs=P(None, None),
            )
        return _padded(f, plan)

    if plan.strategy in ("ring_ag", "ring_rs"):
        axis = plan.axes[0] if len(plan.axes) == 1 else tuple(plan.axes)
        if plan.strategy == "ring_ag":
            # sharded dims: m (rows of a) and n (cols of b)
            f = shard_map(
                lambda xl, wl: ring_ag_matmul(xl, wl, axis,
                                              out_dtype=out_dtype,
                                              local_fn=local_fn),
                mesh=mesh,
                in_specs=(P(axis, None), P(None, axis)),
                out_specs=P(None, axis),
            )
        else:
            # sharded dims: the contraction k and the output rows m
            f = shard_map(
                lambda yl, wl: ring_rs_matmul(yl, wl, axis,
                                              out_dtype=out_dtype,
                                              local_fn=local_fn),
                mesh=mesh,
                in_specs=(P(None, axis), P(axis, None)),
                out_specs=P(axis, None),
            )
        return _padded(f, plan)

    raise ValueError(f"no shard_map lowering rule for {plan.strategy!r}")


def _padded(f, plan: SchedulePlan):
    """Wrap a shard_map program with the plan's zero-pad / slice-back."""

    def run(a, b):
        m, n = a.shape[0], b.shape[1]
        out = f(pad_to(a, plan.pad_a), pad_to(b, plan.pad_b))
        return out[:m, :n] if out.shape != (m, n) else out

    return run


def execute_plan(plan: SchedulePlan, a: jax.Array, b: jax.Array) -> jax.Array:
    """Run ``plan`` on concrete operands, handling leading batch dims.

    a: (batch..., m, k); b: (k, n) or (batch..., k, n).  A batched left
    operand against a 2-D right operand is folded into the rows (vmap of a
    matmul over shared weights IS that bigger matmul); batched-both pairs
    run the 2-D program per flattened batch element.
    """
    if a.shape[-1] != b.shape[-2 if b.ndim > 1 else 0]:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")
    run = lower_shard_map(plan)
    # the span covers tracing of the shard_map body, so every collective
    # recorded at the dist seam inherits the strategy tag
    with obs.span("plan.execute", strategy=plan.strategy,
                  overlap=plan.overlap, m=plan.m, n=plan.n, k=plan.k):
        if a.ndim == 2 and b.ndim == 2:
            return run(a, b)
        if a.ndim > 2 and b.ndim == 2:
            batch = a.shape[:-2]
            m, k = a.shape[-2], a.shape[-1]
            flat = a.reshape((math.prod(batch) * m, k))
            out = run(flat, b)
            return out.reshape(batch + (m, b.shape[-1]))
        if a.ndim == b.ndim and a.ndim > 2 and a.shape[:-2] == b.shape[:-2]:
            batch = a.shape[:-2]
            af = a.reshape((-1,) + a.shape[-2:])
            bf = b.reshape((-1,) + b.shape[-2:])
            # one traced program scanned over the batch, not B dispatches
            out = jax.lax.map(lambda ab: run(ab[0], ab[1]), (af, bf))
            return out.reshape(batch + out.shape[-2:])
    raise ValueError(
        f"unsupported operand ranks for planned matmul: {a.shape} x {b.shape}")
