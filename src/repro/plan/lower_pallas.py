"""Intra-device lowering: wire the plan's tiling order into the Pallas
matmul grid index maps.

A ``SchedulePlan`` carries a ``TilingPlan`` -- the iterated-wreath-product
(Z-order) bits of Sec. 4.3.  ``lower_pallas(plan)`` turns it into the local
block-multiply callable the shard_map bodies run on each device:

  * default tiling -> ``repro.dist.local.local_matmul`` verbatim (already
    Pallas-routed with the Z-order index map on TPU/GPU, fp32-accumulating
    jnp elsewhere) -- bit-identical to the pre-plan engine;
  * overridden tiling (order / blocks / interpret) -> a closure over
    ``repro.kernels.matmul.matmul`` with those arguments, which feeds the
    order into ``zorder_grid_index_map`` via the kernel's scalar-prefetch
    tables; ineligible shapes/backends fall back to the jnp oracle with the
    same fp32-accumulation contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.local import _pallas_eligible, local_matmul

from .ir import SchedulePlan, TilingPlan


def lower_tiling(tiling: TilingPlan):
    """Local-matmul callable executing ``tiling`` (see module docstring)."""
    if tiling.is_default:
        return local_matmul

    def tiled_local_matmul(a: jax.Array, b: jax.Array, *,
                           out_dtype=None) -> jax.Array:
        if out_dtype is None:
            out_dtype = jnp.result_type(a.dtype, b.dtype)
        if _pallas_eligible(a, b) or tiling.interpret and a.ndim == 2:
            from repro.kernels.matmul import matmul as pallas_matmul

            return pallas_matmul(
                a, b, order=tiling.order,
                block_m=tiling.block_m, block_n=tiling.block_n,
                block_k=tiling.block_k, interpret=tiling.interpret,
                out_dtype=out_dtype,
            )
        return jnp.matmul(
            a, b, preferred_element_type=jnp.float32).astype(out_dtype)

    return tiled_local_matmul


def lower_pallas(plan: SchedulePlan):
    """Per-device lowering of ``plan``: its tiling order as a callable."""
    return lower_tiling(plan.tiling)
