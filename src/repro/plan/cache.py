"""Plan cache: memoizes ``build_plan`` so repeated layer calls skip the
cost-model ranking and schedule/permutation construction.

Keys are ``(batch, shapes, dtypes, mesh fingerprint, strategy override,
axes, schedule, tiling)`` -- everything that changes the emitted program.
Stats are exposed for tests and the benchmark smoke job (a dispatch
regression shows up as a miss storm).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional


class PlanCache:
    """A small thread-safe memo table with hit/miss counters."""

    def __init__(self, max_entries: int = 1024):
        self._store: Dict[Any, Any] = {}
        self._lock = threading.Lock()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def get(self, key) -> Optional[Any]:
        with self._lock:
            plan = self._store.get(key)
            if plan is None:
                self.misses += 1
            else:
                self.hits += 1
            return plan

    def put(self, key, plan) -> None:
        with self._lock:
            if len(self._store) >= self.max_entries:
                # drop the oldest insertion (dict preserves order)
                self._store.pop(next(iter(self._store)))
            self._store[key] = plan

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "size": len(self._store)}


plan_cache = PlanCache()


def cache_stats() -> Dict[str, int]:
    return plan_cache.stats()


def cache_clear() -> None:
    plan_cache.clear()
