"""Plan cache: memoizes ``build_plan`` so repeated layer calls skip the
cost-model ranking and schedule/permutation construction.

Keys are ``(batch, shapes, dtypes, mesh fingerprint, strategy override,
axes, schedule, tiling, profile)`` -- everything that changes the emitted
program or its ranking.  Stats are exposed for tests and the benchmark
smoke job (a dispatch regression shows up as a miss storm):
``cache_info()`` is the public functools-style view (hits, misses, size,
evictions, max entries) and is surfaced by ``repro.launch.report`` and the
obs metrics snapshot; when ``repro.obs`` tracing is on, every lookup also
bumps the ``plan.cache.hit`` / ``plan.cache.miss`` / ``plan.cache.evict``
counters.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from repro import obs


class PlanCache:
    """A small thread-safe memo table with hit/miss/eviction counters."""

    def __init__(self, max_entries: int = 1024):
        self._store: Dict[Any, Any] = {}
        self._lock = threading.Lock()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key) -> Optional[Any]:
        with self._lock:
            plan = self._store.get(key)
            if plan is None:
                self.misses += 1
            else:
                self.hits += 1
        if obs.enabled():
            obs.counter("plan.cache.hit" if plan is not None
                        else "plan.cache.miss").inc()
        return plan

    def put(self, key, plan) -> None:
        evicted = False
        with self._lock:
            if key not in self._store and \
                    len(self._store) >= self.max_entries:
                # drop the oldest insertion (dict preserves order)
                self._store.pop(next(iter(self._store)))
                self.evictions += 1
                evicted = True
            self._store[key] = plan
        if evicted and obs.enabled():
            obs.counter("plan.cache.evict").inc()

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def keys(self) -> tuple:
        """Snapshot of the resident plan keys (insertion order).  The
        serving warmup records the keys each (batch, seq) bucket inserted
        so the bucket router can later re-``get`` them per request -- a
        real cache probe that keeps hit-rate accounting honest and
        detects evicted/invalidated warm plans."""
        with self._lock:
            return tuple(self._store)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "size": len(self._store)}

    def info(self) -> Dict[str, int]:
        """functools.lru_cache-style accounting, plus evictions."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "currsize": len(self._store),
                    "maxsize": self.max_entries,
                    "evictions": self.evictions}


plan_cache = PlanCache()


def cache_stats() -> Dict[str, int]:
    return plan_cache.stats()


def cache_info() -> Dict[str, int]:
    """Public hit/miss/size/eviction accounting of the process-global plan
    cache (see ``PlanCache.info``)."""
    return plan_cache.info()


def cache_clear() -> None:
    plan_cache.clear()
