"""Opt-in mesh context routing layer matmuls through the plan engine.

``repro.layers.linear`` (and everything built on it: mlp, attention, moe)
checks ``planned_mesh()``: inside a ``planned_matmuls(mesh)`` scope its
x @ w products dispatch through ``repro.plan`` -- cost-model-ranked
strategy, plan cache, batch folding -- instead of the purely local
multiply.  Outside the scope nothing changes (the GSPMD baseline path).
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional

_PLAN_MESH: ContextVar[Optional[object]] = ContextVar(
    "repro_plan_mesh", default=None)


def planned_mesh():
    """The mesh layer matmuls should plan against, or None (local path)."""
    return _PLAN_MESH.get()


@contextlib.contextmanager
def planned_matmuls(mesh):
    """Route layer matmuls through ``repro.plan`` on ``mesh`` within scope."""
    token = _PLAN_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _PLAN_MESH.reset(token)
