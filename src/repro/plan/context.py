"""Opt-in mesh context routing layer matmuls through the plan engine.

``repro.layers.linear`` (and everything built on it: mlp, attention, moe)
checks ``planned_mesh()``: inside a ``planned_matmuls(mesh)`` scope its
x @ w products dispatch through ``repro.plan`` -- cost-model-ranked
strategy, plan cache, batch folding -- instead of the purely local
multiply.  Outside the scope nothing changes (the GSPMD baseline path).

``planned_matmuls(mesh, strategy=...)`` additionally pins every in-scope
product to one strategy instead of letting the cost model rank -- the
sweep harness (`benchmarks/serve_sweep.py`) uses this to measure serving
throughput per strategy cell.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Tuple

_PLAN_SCOPE: ContextVar[
    Optional[Tuple[object, Optional[str], Optional[object]]]] = ContextVar(
    "repro_plan_scope", default=None)


def planned_mesh():
    """The mesh layer matmuls should plan against, or None (local path)."""
    scope = _PLAN_SCOPE.get()
    return None if scope is None else scope[0]


def planned_strategy() -> Optional[str]:
    """The strategy override pinned by the enclosing ``planned_matmuls``
    scope, or None (the cost model ranks)."""
    scope = _PLAN_SCOPE.get()
    return None if scope is None else scope[1]


def planned_tuning():
    """The tuning table/tuner the enclosing ``planned_matmuls`` scope
    supplies to ``build_plan``, or None (peak-FLOPs compute model)."""
    scope = _PLAN_SCOPE.get()
    return None if scope is None else scope[2]


@contextlib.contextmanager
def planned_matmuls(mesh, strategy: Optional[str] = None, tuning=None):
    """Route layer matmuls through ``repro.plan`` on ``mesh`` within scope;
    ``strategy`` optionally pins the schedule instead of cost-model
    ranking (validated per shape by ``build_plan`` at dispatch time);
    ``tuning`` (a ``repro.tune`` table or live ``Tuner``) prices the
    compute side of in-scope plans with measured kernel seconds."""
    token = _PLAN_SCOPE.set((mesh, strategy, tuning))
    try:
        yield mesh
    finally:
        _PLAN_SCOPE.reset(token)
