"""repro.plan -- the unified schedule-plan IR.

The paper's thesis is that one algebraic object -- an equivariant map fixed
by a homomorphism of the iteration-space symmetry group -- describes a
matmul schedule at every machine level.  ``SchedulePlan`` reifies that
object as a compiler IR sitting between the solver (``repro.core``) and the
two machine levels it lowers to:

    solver (repro.core)  -->  SchedulePlan  -->  lower_shard_map  (inter-chip)
                                          \\->  lower_pallas     (intra-chip)

IR field -> paper object:

  ``strategy``             the solution family of the equivariance
                           equations being executed (Cannon, SUMMA's
                           broadcast contrast class, the 1-D ring
                           solutions, the 2.5D composition)
  ``axes`` / ``grid``      the network group N = (Z/qZ)^d the schedule is
                           equivariant under, named as mesh axes
  ``torus.skew_a/b``       the initial placement l_I -- each block's device
                           is a coset representative of its stabilizer
  ``torus.step_*``         the movement homomorphism's image: the constant
                           network translation mu each variable set
                           performs per time step, as ppermute (src, dst)
                           pairs
  ``torus.collect_c``      l_I^{-1} after t steps -- the inverse coset map
                           restoring canonical layout (empty when C is
                           stationary, e.g. Cannon)
  ``replication``          the Sec.-2.5 memory-for-communication trade:
                           c-fold operand copies along the pod axis
  ``tiling``               the iterated-wreath-product homomorphism of
                           Sec. 4.3 -- low-order index bits lifted to small
                           time steps, i.e. the Z-order (Morton) bits of
                           the intra-device block traversal
  ``cost``                 the word-count Estimate that ranked this
                           strategy (the paper's communication-cost
                           functional on schedules)

``build_plan`` is the planner (topology filters, the cost model ranks);
``execute_plan`` folds leading batch dims and runs the shard_map lowering;
the plan cache memoizes all of it per (shapes, dtypes, mesh fingerprint,
strategy override).  ``repro.dist.api.symmetric_matmul`` is a thin facade
over this package, and ``planned_matmuls`` routes the layer library's
x @ w products through it.
"""
from .cache import (PlanCache, cache_clear, cache_info, cache_stats,
                    plan_cache)
from .context import (planned_matmuls, planned_mesh, planned_strategy,
                      planned_tuning)
from .ir import (SchedulePlan, TilingPlan, TorusProgram, build_plan,
                 mesh_candidates, mesh_fingerprint, rank_mesh_strategies,
                 strategy_seconds)
from .lower_pallas import lower_pallas, lower_tiling
from .lower_shard_map import execute_plan, lower_shard_map, on_lower

# the plan package's cost model is the dist analytic model; re-exported so
# consumers (runtime.sharding, models.sharding_rules) can "consult
# plan.estimate" without reaching into repro.dist
from repro.dist.api import Estimate, estimate  # noqa: E402  (cycle-safe)

__all__ = [
    "SchedulePlan", "TilingPlan", "TorusProgram", "build_plan",
    "mesh_candidates", "mesh_fingerprint", "rank_mesh_strategies",
    "strategy_seconds",
    "execute_plan", "lower_shard_map", "on_lower", "lower_pallas",
    "lower_tiling",
    "PlanCache", "plan_cache", "cache_stats", "cache_info", "cache_clear",
    "planned_matmuls", "planned_mesh", "planned_strategy", "planned_tuning",
    "Estimate", "estimate",
]
