"""SchedulePlan: the reified schedule IR.

``build_plan`` is the planner: it takes a global matmul (shapes, dtypes,
batching) plus a mesh, picks a strategy (cost-model-ranked, topology only as
a filter), and materializes everything the two lowerings need -- mesh-axis
roles, the torus program's placement/movement/collection permutations,
replication factor, padding multiples, and the intra-device tiling order.
Plans are immutable and hashable; ``repro.plan.cache`` memoizes them on
``(batch, shapes, dtypes, mesh fingerprint, strategy override)``.
"""
from __future__ import annotations

import dataclasses
import math
import time
import weakref
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro import obs
from repro.core.schedule import TorusSchedule, cannon_schedule
from repro.dist.api import Estimate, estimate, overlap_capability

Perm = Tuple[Tuple[int, int], ...]


def _freeze_perm(perm) -> Perm:
    return tuple((int(s), int(d)) for s, d in perm) if perm is not None else ()


@dataclasses.dataclass(frozen=True)
class TorusProgram:
    """The complete ppermute program of a ``TorusSchedule`` as static data.

    Paper mapping: ``skew_*`` are the initial placements l_I (one coset
    representative per block), ``step_*`` the one-step images of the movement
    homomorphism mu, ``collect_c`` the inverse layout restore (empty when C is
    stationary in canonical layout, e.g. Cannon).
    """

    q: int
    steps: int
    shifts: Tuple[Tuple[str, Tuple[int, int]], ...]  # {var: mu} as items
    skew_a: Perm
    skew_b: Perm
    step_a: Perm
    step_b: Perm
    step_c: Perm
    collect_c: Perm

    @classmethod
    def from_schedule(cls, schedule: TorusSchedule) -> "TorusProgram":
        from repro.dist.cannon import lowered_plan

        p = lowered_plan(schedule)
        return cls(
            q=p["q"],
            steps=p["steps"],
            shifts=tuple(sorted(
                (v, (int(mu[0]), int(mu[1]))) for v, mu in p["shifts"].items()
            )),
            skew_a=_freeze_perm(p["skew"]["A"]),
            skew_b=_freeze_perm(p["skew"]["B"]),
            step_a=_freeze_perm(p["step_perm"]["A"]),
            step_b=_freeze_perm(p["step_perm"]["B"]),
            step_c=_freeze_perm(p["step_perm"]["C"]),
            collect_c=_freeze_perm(p["collect_C"]),
        )


@dataclasses.dataclass(frozen=True)
class TilingPlan:
    """Intra-device (HBM -> VMEM) traversal: the wreath-product bits.

    ``order="zorder"`` is the paper's Sec.-4.3 space-bounded schedule (Morton
    bits of the output-block grid); ``rowmajor`` the baseline.  ``block_*``
    override the kernel's VMEM-fitting defaults.  ``tuned`` marks blocks the
    planner substituted from a measured ``repro.tune`` table (the autotune
    winner for the plan's local kernel bucket).  The default plan lowers to
    ``repro.dist.local.local_matmul`` verbatim (which already routes Pallas
    with the Z-order index map when eligible), keeping the numerics of the
    pre-plan engine bit-for-bit.
    """

    order: str = "zorder"
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    block_k: Optional[int] = None
    interpret: bool = False
    tuned: bool = False

    @property
    def is_default(self) -> bool:
        return (self.order == "zorder" and self.block_m is None
                and self.block_n is None and self.block_k is None
                and not self.interpret and not self.tuned)


def mesh_fingerprint(mesh) -> Optional[Tuple]:
    """Hashable identity of a mesh: axis names/sizes, device ids, platform.
    Two meshes with equal fingerprints execute plans identically.  Memoized
    per mesh object (jax meshes are hashable) so the per-dispatch cache-key
    construction does not walk the device array every call."""
    if mesh is None:
        return None
    try:
        return _mesh_fingerprint_cached(mesh)
    except TypeError:  # unhashable/unweakrefable mesh stand-in: compute directly
        return _mesh_fingerprint_uncached(mesh)


# Keyed on weakrefs so the memo never pins a mesh (and its device handles /
# buffers) past its natural lifetime -- elastic re-meshing
# (``runtime/elastic.py``) churns through meshes, and an lru_cache here would
# keep the last 64 of them alive for the whole process.
_mesh_fingerprint_memo: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _mesh_fingerprint_cached(mesh) -> Tuple:
    fp = _mesh_fingerprint_memo.get(mesh)  # TypeError if unweakrefable
    if fp is None:
        fp = _mesh_fingerprint_uncached(mesh)
        _mesh_fingerprint_memo[mesh] = fp
    return fp


def _mesh_fingerprint_uncached(mesh) -> Tuple:
    names = tuple(mesh.axis_names)
    sizes = tuple(int(mesh.shape[a]) for a in names)
    devs = tuple(
        int(getattr(d, "id", i))
        for i, d in enumerate(getattr(mesh, "devices", ()).flat)
    ) if hasattr(getattr(mesh, "devices", None), "flat") else ()
    platform = getattr(
        getattr(mesh, "devices", None), "flat", [None])[0] if devs else None
    platform = getattr(platform, "platform", None)
    return (names, sizes, devs, platform)


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """One planned global matmul: (batch..., m, k) x (k, n) on ``mesh``.

    Fields (paper object in brackets):
      strategy     -- solution family executed [the equivariant map f]
      axes / grid  -- mesh-axis roles and sizes [the network group N]
      torus        -- placement/movement/collection perms [l_I, mu, l_I^-1]
      replication  -- operand copies along the pod axis [Sec.-2.5 c-fold]
      tiling       -- intra-device Z-order bits [iterated wreath product]
      pad_a/pad_b  -- block-multiple padding taking the problem onto the grid
      cost         -- the analytic Estimate that ranked this strategy
      overlap      -- execute the double-buffered lowering [max(comp, comm)]
      axis_roles   -- hierarchical (axis, role) pairs [the wreath levels]:
                      ``tree`` is an inter-pod (DCN-class) axis, ``pod`` a
                      replication axis, ``row``/``col`` the intra-pod torus
                      pair, ``ring`` a flattened-ring member
    """

    strategy: str
    m: int
    n: int
    k: int
    batch: Tuple[int, ...]
    out_dtype: Any
    mesh: Any = dataclasses.field(repr=False)
    mesh_fp: Optional[Tuple] = None
    axes: Tuple[str, ...] = ()
    grid: Tuple[int, ...] = ()
    axis_roles: Tuple[Tuple[str, str], ...] = ()
    replication: int = 1
    pad_a: Tuple[int, int] = (1, 1)
    pad_b: Tuple[int, int] = (1, 1)
    schedule: Optional[TorusSchedule] = None
    torus: Optional[TorusProgram] = None
    tiling: TilingPlan = TilingPlan()
    cost: Optional[Estimate] = None
    overlap: bool = False


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def _square_axes(mesh, names) -> bool:
    return mesh.shape[names[0]] == mesh.shape[names[1]]


# per-strategy role sequence over the resolved axes, leading axis first
_AXIS_ROLE_SEQ = {
    "cannon": ("row", "col"),
    "torus": ("row", "col"),
    "summa": ("row", "col"),
    "cannon25d": ("pod", "row", "col"),
    "pod25d": ("pod", "row", "col"),
    "fattree": ("tree", "row", "col"),
}


def _axis_roles(strategy: str,
                ax: Tuple[str, ...]) -> Tuple[Tuple[str, str], ...]:
    """Hierarchical (axis, role) pairs for ``strategy`` over resolved axes
    ``ax`` -- the machine hierarchy the lowering will drive collectives
    over.  Ring strategies flatten every axis into one logical ring; custom
    torus schedules reuse the cannon roles."""
    if strategy in ("ring_ag", "ring_rs"):
        return tuple((a, "ring") for a in ax)
    seq = _AXIS_ROLE_SEQ.get(strategy, ("row", "col"))
    return tuple(zip(ax, seq))


def mesh_candidates(mesh) -> Tuple[str, ...]:
    """Strategies executable on ``mesh`` -- the topology *filter* (ranking is
    the cost model's job, see ``choose``).  Ring strategies run on any mesh
    (all axes flattened into one logical ring); 2-D torus strategies need two
    axes (Cannon a square pair); the 2.5D family needs a pod axis plus an
    in-layer pair; the fat-tree needs a power-of-two inter-pod tree axis
    over an intra-pod pair."""
    if mesh.size <= 1:
        return ("local",)
    names = tuple(mesh.axis_names)
    cands = ["ring_ag", "ring_rs"]
    if len(names) == 2:
        if _square_axes(mesh, names):
            cands.append("cannon")
        cands.append("summa")
    if len(names) >= 3:
        if mesh.shape[names[1]] == mesh.shape[names[2]]:
            cands.append("cannon25d")
        cands.append("pod25d")
        s = mesh.shape[names[0]]
        if s >= 2 and (s & (s - 1)) == 0:
            cands.append("fattree")
    return tuple(cands)


def _grid_for(mesh, strategy: str,
              ax: Tuple[str, ...]) -> Optional[Tuple[int, ...]]:
    """The device-grid factorization the lowering would run ``strategy``
    on over the resolved axes ``ax``, so the estimate prices the real
    program (a 2x8 mesh's SUMMA is a 2x8 SUMMA, not the canonical 4x4 of
    tp=16)."""
    if strategy in ("cannon", "summa", "cannon25d", "pod25d", "fattree"):
        return tuple(mesh.shape[a] for a in ax)
    return None  # ring family / local: only mesh.size matters


def _local_kernel_shape(strategy: str, grid, m: int, n: int, k: int,
                        tp: int) -> Tuple[int, int, int]:
    """The (m, n, k) of ONE local block-multiply call under ``strategy`` on
    ``grid`` -- the shape the Pallas kernel actually sees per step, hence
    the shape the tuning table is consulted at.  Ceil-division approximates
    the padded shard dims; ring/pod strategies with no 2-D grid use ``tp``."""

    def cdiv(x, d):
        return max(-(-int(x) // max(int(d), 1)), 1)

    if strategy in ("cannon", "torus"):
        q = grid[0] if grid else max(int(round(math.sqrt(max(tp, 1)))), 1)
        return cdiv(m, q), cdiv(n, q), cdiv(k, q)
    if strategy == "summa":
        qx, qy = grid[0], grid[1]
        return cdiv(m, qx), cdiv(n, qy), cdiv(k, qx * qy)
    if strategy == "cannon25d":
        c, q = grid[0], grid[1]
        return cdiv(m, q), cdiv(n, q), cdiv(k, c * q)
    if strategy == "pod25d":
        if grid and len(grid) >= 3:
            c, qx, qy = grid[0], grid[1], grid[2]
            return cdiv(m, qx), cdiv(n, qy), cdiv(k, c * qx * qy)
        c = grid[0] if grid else max(tp, 1)
        return m, n, cdiv(k, c)
    if strategy == "fattree":
        s, qx, qy = grid[0], grid[1], grid[2]
        return cdiv(m, qx), cdiv(n, s * qy), cdiv(k, s * qx * qy)
    if strategy == "ring_ag":
        t = grid[0] if grid else max(tp, 1)
        return cdiv(m, t), cdiv(n, t), k
    if strategy == "ring_rs":
        t = grid[0] if grid else max(tp, 1)
        return m, n, cdiv(k, t)
    return m, n, k  # local


def _measured_compute_s(tuning, strategy: str, grid, m: int, n: int, k: int,
                        tp: int, dtype) -> Optional[float]:
    """Total measured local-compute seconds for one strategy cell: the
    tuned per-call kernel seconds (bucket-scaled) times the number of
    local block-multiply calls covering the 2mnk/tp local FLOPs.  None
    when no tuning is given or its table misses the bucket (a live
    ``repro.tune.Tuner`` searches instead of missing)."""
    if tuning is None:
        return None
    lm, ln, lk = _local_kernel_shape(strategy, grid, m, n, k, tp)
    dname = jnp.dtype(dtype if dtype is not None else jnp.float32).name
    per_call = tuning.compute_seconds(lm, ln, lk, dtype=dname)
    if per_call is None:
        return None
    calls = max((2.0 * m * n * k / max(tp, 1)) / (2.0 * lm * ln * lk), 1.0)
    return per_call * calls


def strategy_seconds(est: Estimate, mesh, *, profile=None, tuning=None,
                     dtype=None) -> float:
    """The calibrated ranking key for one ``Estimate`` on ``mesh``: fitted
    α–β comm seconds with the compute term replaced by measured
    tuned-kernel seconds wherever ``tuning`` covers the strategy's local
    kernel bucket.  With tuning but no profile, comm is priced analytically
    (``default_profile``).  This IS the sort key ``rank_mesh_strategies``
    uses, exported so drift checks and reports can reproduce it."""
    eff = profile
    if eff is None and tuning is not None:
        from repro.obs.profile import default_profile

        eff = default_profile()
    if eff is None:
        return est.total_s
    cs = None
    if tuning is not None:
        ax = _plan_axes(mesh, est.strategy, None)
        cs = _measured_compute_s(tuning, est.strategy,
                                 _grid_for(mesh, est.strategy, ax),
                                 est.m, est.n, est.k, est.tp, dtype)
    return eff.seconds(est, compute_s=cs)


def rank_mesh_strategies(m: int, n: int, k: int, mesh,
                         dtype_bytes: int = 2, *,
                         profile=None, tuning=None,
                         dtype=None) -> Tuple[Estimate, ...]:
    """Mesh-applicable strategies priced by ``estimate`` on the grids they
    would actually execute, cheapest first.

    With a calibrated ``profile`` (``repro.obs.MachineProfile``) the sort
    key is measured seconds -- the fitted α–β applied to each estimate's
    analytic bytes/message counts -- instead of the datasheet-constant
    ``total_s``; the estimates themselves (the word counts conformance
    checks) are identical either way.  Each estimate carries the resolved
    mesh-axis roles (``comm_by_axis``), so a profile with per-axis
    ``axis:{name}`` link classes prices every term on its own link.

    ``tuning`` (a ``repro.tune`` table/tuner, defaulting to the profile's
    embedded table) additionally replaces each strategy's peak-FLOPs
    compute term with measured kernel seconds at its local bucket --
    ``dtype`` names the operand dtype the table is keyed on (fp32 when
    omitted).  See ``strategy_seconds``.
    """
    cands = mesh_candidates(mesh)
    ests = []
    for s in cands:
        ax = _plan_axes(mesh, s, None)
        ests.append(estimate(s, m, n, k, mesh.size, dtype_bytes,
                             grid=_grid_for(mesh, s, ax), axes=ax))
    if tuning is None:
        tuning = getattr(profile, "tuning", None)
    if profile is not None or tuning is not None:
        key = lambda e: (strategy_seconds(e, mesh, profile=profile,  # noqa: E731
                                          tuning=tuning, dtype=dtype),
                         cands.index(e.strategy))
    else:
        key = lambda e: (e.total_s, cands.index(e.strategy))  # noqa: E731
    ests.sort(key=key)
    return tuple(ests)


# strategies with a shard_map lowering rule (xla_ag/xla_rs exist only in
# the cost model; forcing them is rejected at plan time)
_EXECUTABLE = frozenset(
    ("cannon", "summa", "cannon25d", "pod25d", "fattree", "ring_ag",
     "ring_rs", "local"))

# minimum mesh-axis count per strategy, for early clear errors
_MIN_AXES = {"cannon": 2, "summa": 2, "cannon25d": 3, "pod25d": 1,
             "fattree": 3, "ring_ag": 1, "ring_rs": 1}


def _plan_axes(mesh, strategy: str, axes: Optional[Tuple[str, ...]]):
    """Resolve mesh-axis roles for ``strategy`` (explicit ``axes`` wins)."""
    names = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    need = _MIN_AXES.get(strategy, 0)
    if len(names) < need:
        raise ValueError(
            f"strategy {strategy!r} needs a mesh with >= {need} axes; "
            f"got {names}")
    if axes is not None:
        return names
    if strategy in ("cannon", "summa"):
        return names[:2]
    if strategy in ("cannon25d", "fattree"):
        return names[:3]
    if strategy == "pod25d":
        rest = names[1:]
        return (names[0],) + (rest[:2] if len(rest) >= 2 else ())
    if strategy in ("ring_ag", "ring_rs"):
        return names  # all axes flattened into one logical ring
    return ()


def build_plan(
    m: int, n: int, k: int, *,
    mesh=None,
    strategy: Optional[str] = None,
    batch: Tuple[int, ...] = (),
    a_dtype=jnp.float32,
    b_dtype=jnp.float32,
    out_dtype=None,
    axes: Optional[Tuple[str, ...]] = None,
    schedule: Optional[TorusSchedule] = None,
    tiling: Optional[TilingPlan] = None,
    profile=None,
    tuning=None,
    overlap: Optional[bool] = None,
    use_cache: bool = True,
) -> SchedulePlan:
    """Plan a global (batch..., m, k) x (k, n) matmul on ``mesh``.

    Strategy selection ranks the mesh-applicable candidates with the analytic
    cost model (``strategy`` forces one; ``schedule`` forces a custom torus
    schedule; a calibrated ``profile`` -- ``repro.obs.MachineProfile`` --
    makes the ranking use measured seconds instead of datasheet constants,
    without changing any plan's word counts).  ``overlap`` selects the
    double-buffered lowering: ``None`` (default) lets the planner pick --
    overlapped exactly when the cost model (calibrated when ``profile`` is
    given) predicts ``max(compute, comm) < compute + comm`` strictly --
    ``False`` forces the staged twin, ``True`` demands overlap and raises
    for strategies with no overlapped body.  ``tuning`` (a
    ``repro.tune.TuningTable`` or live ``Tuner``; defaults to the
    profile's embedded table) swaps the compute term of both decisions for
    measured kernel seconds at each strategy's local bucket and folds the
    winning blocks into the plan's ``TilingPlan``.  Results are memoized --
    see ``repro.plan.cache``.  Under ``repro.obs`` tracing every call is a
    ``plan.build`` span and cache misses record their build time in the
    ``plan.build_us`` histogram.
    """
    from .cache import plan_cache

    if out_dtype is None:
        out_dtype = jnp.result_type(a_dtype, b_dtype)
    out_dtype = jnp.dtype(out_dtype)
    tiling = tiling if tiling is not None else TilingPlan()
    key = (
        "plan", batch, m, n, k, jnp.dtype(a_dtype).name, jnp.dtype(b_dtype).name,
        out_dtype.name, mesh_fingerprint(mesh), strategy, axes, schedule, tiling,
        profile, tuning, overlap,
    )
    with obs.span("plan.build", m=m, n=n, k=k, strategy=strategy or "auto"):
        if use_cache:
            cached = plan_cache.get(key)
            if cached is not None:
                return cached
        t0 = time.perf_counter()
        plan = _build_plan_uncached(
            m, n, k, mesh=mesh, strategy=strategy, batch=batch,
            a_dtype=a_dtype, out_dtype=out_dtype, axes=axes,
            schedule=schedule, tiling=tiling, profile=profile,
            tuning=tuning, overlap=overlap,
        )
        if obs.enabled():
            obs.histogram("plan.build_us").observe(
                (time.perf_counter() - t0) * 1e6)
            obs.instant("plan.built", strategy=plan.strategy)
        if use_cache:
            plan_cache.put(key, plan)
    return plan


def _resolve_overlap(strategy: str, grid, cost: Optional[Estimate],
                     overlap: Optional[bool], profile,
                     tuning=None, dtype=None) -> bool:
    """Pick the executed variant: the caller's explicit choice (validated
    against the lowering's capability), or -- when ``overlap`` is None --
    the planner's: overlapped exactly when the cost model predicts a
    strict ``max(compute, comm) < compute + comm`` win (calibrated seconds
    when a profile is given, measured tuned-kernel compute when ``tuning``
    covers the local bucket; ties go to the staged body).  The ring chains
    have no staged twin -- their fused one-hop programs are the overlap."""
    capability = overlap_capability(strategy, grid)
    if overlap is not None:
        if overlap and not capability:
            raise ValueError(
                f"strategy {strategy!r} (grid={grid}) has no overlapped "
                "lowering")
        if not overlap and strategy in ("ring_ag", "ring_rs"):
            raise ValueError(
                f"{strategy} is intrinsically overlapped (the fused ring "
                "chain has no staged twin)")
        return bool(overlap)
    if not capability:
        return False
    if strategy in ("ring_ag", "ring_rs"):
        return True
    if cost is None:
        # custom torus schedules carry no estimate; any torus program
        # double-buffers, and overlap never loses words -- default to it
        return True
    staged = dataclasses.replace(cost, overlapped=False)
    over = dataclasses.replace(cost, overlapped=True)
    eff = profile
    if eff is None and tuning is not None:
        from repro.obs.profile import default_profile

        eff = default_profile()
    if eff is not None:
        cs = _measured_compute_s(tuning, strategy, grid, cost.m, cost.n,
                                 cost.k, cost.tp, dtype)
        return eff.seconds(over, compute_s=cs) < eff.seconds(
            staged, compute_s=cs)
    return over.total_s < staged.total_s


def _tuned_tiling(tiling: TilingPlan, tuning, strategy: str, grid,
                  m: int, n: int, k: int, tp: int, dtype) -> TilingPlan:
    """Swap a default ``TilingPlan`` for the measured winner's blocks/order
    when the tuning table covers the plan's local kernel bucket (a live
    ``Tuner`` searches the bucket on demand -- this is where serve-warmup
    tuning happens).  Explicit tilings always win over the table."""
    if tuning is None or not tiling.is_default:
        return tiling
    lm, ln, lk = _local_kernel_shape(strategy, grid, m, n, k, tp)
    entry = tuning.entry_for(lm, ln, lk, dtype=jnp.dtype(dtype).name)
    if entry is None:
        return tiling
    return TilingPlan(order=entry.order, block_m=entry.block_m,
                      block_n=entry.block_n, block_k=entry.block_k,
                      tuned=True)


def _build_plan_uncached(m, n, k, *, mesh, strategy, batch, a_dtype,
                         out_dtype, axes, schedule, tiling,
                         profile=None, tuning=None,
                         overlap=None) -> SchedulePlan:
    flat_m = m * math.prod(batch) if batch else m
    dtype_bytes = jnp.dtype(a_dtype).itemsize
    cost = None
    if tuning is None:
        tuning = getattr(profile, "tuning", None)
    if schedule is not None and mesh is None:
        raise ValueError("executing a TorusSchedule requires a mesh")
    if (mesh is None or mesh.size == 1) and schedule is None:
        if overlap:
            raise ValueError(
                "local/single-device plans have no overlapped lowering")
        return SchedulePlan(
            strategy="local", m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            tiling=_tuned_tiling(tiling, tuning, "local", None,
                                 flat_m, n, k, 1, a_dtype),
            cost=estimate("local", flat_m, n, k, 1, dtype_bytes),
        )
    if schedule is not None:
        strategy = strategy or "torus"
        ax = _plan_axes(mesh, "cannon", axes)
        resolved = _resolve_overlap("cannon", (schedule.q, schedule.q),
                                    None, overlap, profile, tuning, a_dtype)
        tiling = _tuned_tiling(tiling, tuning, "cannon",
                               (schedule.q, schedule.q), flat_m, n, k,
                               schedule.q * schedule.q, a_dtype)
        return _torus_plan(m, n, k, batch, out_dtype, mesh, ax, schedule,
                           tiling, cost=None, strategy=strategy,
                           overlap=resolved)
    if strategy is None:
        ranked = rank_mesh_strategies(flat_m, n, k, mesh, dtype_bytes,
                                      profile=profile, tuning=tuning,
                                      dtype=a_dtype)
        cost = ranked[0]
        strategy = cost.strategy
    elif strategy in _EXECUTABLE:
        ax_cost = _plan_axes(mesh, strategy, axes)
        cost = estimate(strategy, flat_m, n, k, mesh.size, dtype_bytes,
                        grid=_grid_for(mesh, strategy, ax_cost),
                        axes=ax_cost)
    else:
        raise ValueError(
            f"cannot plan strategy {strategy!r}; executable strategies are "
            f"{sorted(_EXECUTABLE)}")

    ax = _plan_axes(mesh, strategy, axes)
    resolved = _resolve_overlap(strategy, _grid_for(mesh, strategy, ax),
                                cost, overlap, profile, tuning, a_dtype)
    tiling = _tuned_tiling(tiling, tuning, strategy,
                           _grid_for(mesh, strategy, ax), flat_m, n, k,
                           mesh.size, a_dtype)
    if cost is not None:
        # the plan's cost prices the variant it will execute, so
        # ``plan.cost.overlapped == plan.overlap`` always holds
        cost = dataclasses.replace(cost, overlapped=resolved)
    if strategy == "local":
        return SchedulePlan(
            strategy="local", m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            tiling=tiling, cost=cost,
        )
    if strategy == "cannon":
        q = mesh.shape[ax[0]]
        return _torus_plan(m, n, k, batch, out_dtype, mesh, ax,
                           cannon_schedule(q), tiling, cost,
                           strategy="cannon", overlap=resolved)
    if strategy == "summa":
        qx, qy = mesh.shape[ax[0]], mesh.shape[ax[1]]
        return SchedulePlan(
            strategy="summa", m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            axes=ax, grid=(qx, qy), axis_roles=_axis_roles("summa", ax),
            pad_a=(qx, qx * qy), pad_b=(qx * qy, qy),
            tiling=tiling, cost=cost, overlap=resolved,
        )
    if strategy == "fattree":
        s = mesh.shape[ax[0]]
        if s < 2 or s & (s - 1):
            raise ValueError(
                f"fat-tree needs a power-of-two tree axis with >= 2 pods; "
                f"axis {ax[0]!r} has size {s}")
        qx, qy = mesh.shape[ax[1]], mesh.shape[ax[2]]
        return SchedulePlan(
            strategy="fattree", m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            axes=ax, grid=(s, qx, qy),
            axis_roles=_axis_roles("fattree", ax),
            pad_a=(qx, s * qx * qy), pad_b=(s * qx * qy, s * qy),
            tiling=tiling, cost=cost, overlap=resolved,
        )
    if strategy == "cannon25d":
        c = mesh.shape[ax[0]]
        q = mesh.shape[ax[1]]
        if mesh.shape[ax[2]] != q:
            raise ValueError("in-layer Cannon needs a square (q x q) layer")
        sched = cannon_schedule(q)
        return SchedulePlan(
            strategy="cannon25d", m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            axes=ax, grid=(c, q, q), replication=c,
            axis_roles=_axis_roles("cannon25d", ax),
            pad_a=(q, c * q), pad_b=(c * q, q),
            schedule=sched, torus=TorusProgram.from_schedule(sched),
            tiling=tiling, cost=cost, overlap=resolved,
        )
    if strategy == "pod25d":
        c = mesh.shape[ax[0]]
        if len(ax) >= 3:
            qx, qy = mesh.shape[ax[1]], mesh.shape[ax[2]]
            return SchedulePlan(
                strategy="pod25d", m=m, n=n, k=k, batch=tuple(batch),
                out_dtype=out_dtype, mesh=mesh,
                mesh_fp=mesh_fingerprint(mesh),
                axes=ax, grid=(c, qx, qy), replication=c,
                axis_roles=_axis_roles("pod25d", ax),
                pad_a=(qx, c * qx * qy), pad_b=(c * qx * qy, qy),
                tiling=tiling, cost=cost, overlap=resolved,
            )
        return SchedulePlan(
            strategy="pod25d", m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            axes=ax[:1], grid=(c,), replication=c,
            axis_roles=_axis_roles("pod25d", ax[:1]),
            pad_a=(1, c), pad_b=(c, 1),
            tiling=tiling, cost=cost, overlap=resolved,
        )
    if strategy in ("ring_ag", "ring_rs"):
        t = 1
        for a_ in ax:
            t *= mesh.shape[a_]
        pad_a = (t, 1) if strategy == "ring_ag" else (t, t)
        pad_b = (1, t) if strategy == "ring_ag" else (t, 1)
        return SchedulePlan(
            strategy=strategy, m=m, n=n, k=k, batch=tuple(batch),
            out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
            axes=ax, grid=(t,), axis_roles=_axis_roles(strategy, ax),
            pad_a=pad_a, pad_b=pad_b,
            tiling=tiling, cost=cost, overlap=resolved,
        )
    raise ValueError(f"cannot plan strategy {strategy!r}")


def _torus_plan(m, n, k, batch, out_dtype, mesh, ax, schedule, tiling, cost,
                *, strategy, overlap: bool = False) -> SchedulePlan:
    q = schedule.q
    if mesh.shape[ax[0]] != q or mesh.shape[ax[1]] != q:
        raise ValueError(
            f"mesh axes ({mesh.shape[ax[0]]}, {mesh.shape[ax[1]]}) "
            f"do not span the schedule's {q} x {q} torus")
    if schedule.t != q:
        raise ValueError("executor supports the t = q schedule family")
    return SchedulePlan(
        strategy=strategy, m=m, n=n, k=k, batch=tuple(batch),
        out_dtype=out_dtype, mesh=mesh, mesh_fp=mesh_fingerprint(mesh),
        axes=tuple(ax[:2]), grid=(q, q),
        axis_roles=_axis_roles("torus", tuple(ax[:2])),
        pad_a=(q, q), pad_b=(q, q),
        schedule=schedule, torus=TorusProgram.from_schedule(schedule),
        tiling=tiling, cost=cost, overlap=overlap,
    )
